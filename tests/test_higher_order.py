"""Higher-order delta views (delta-of-delta, ISSUE 8): the property +
regression suite across every execution path.

Covers, per ISSUE 8's satellite checklist:
  * depth-1/2/3 engines stay exact against re-evaluation for every
    ``apps/`` program family under hypothesis-generated random update
    streams (ragged batches, mixed ranks), on the REPRO_CHAOS_SEEDS
    matrix;
  * the symbolic Δᵏ hierarchy: auxiliary-view registration, degree
    termination (Δ^{d+1} ≡ 0), the materialized Δ² trigger against the
    numeric second difference, and the inverse (Woodbury) unsupported
    path;
  * the TriggerCache order-collision fix (namespace + depth-keyed delta
    tails) with a concurrent regression test;
  * planner depth pricing (``WorkloadDescriptor.max_order``), the
    ``AdaptivePlanner`` reads-per-firing fit, plan-driven engine depth
    adoption, and the fleet scheduler's amortized pricing.

Tolerances: the ISSUE's "within 1e-6" target is met scale-normalized
(max |inc − ref| / max |ref|) for every polynomial family — the engines
run float32, so the absolute bound only holds relative to the views'
magnitude.  The OLS family goes through a float32 Woodbury inverse and
uses the repo-standard 2e-3 (same bound the first-order suites apply).
"""

import os
import threading
from dataclasses import replace

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.apps import (build_bgd_program, build_general_program,
                        build_ols_program, build_pagerank_program,
                        build_powers_program, build_sums_program)
from repro.core import (IncrementalEngine, IncrementalInverseError,
                        ReevalEngine, compile_delta_trigger, compile_program,
                        delta_view_name, max_abs_diff)
from repro.plan import (AdaptivePlanner, TriggerCache, ViewPlan,
                        WorkloadDescriptor, firing_cost_flops, plan_program)

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("REPRO_CHAOS_SEEDS", "0").split(",")]

# family → (program builder, updatable inputs, per-input init scale,
#           scale-normalized tolerance)
FAMILIES = {
    "powers_exp": (lambda: build_powers_program(4, 12), ("A",),
                   {"A": 0.25}, 1e-6),
    "sums_powers": (lambda: build_sums_program(4, 10), ("A",),
                    {"A": 0.25}, 1e-6),
    "general_form": (lambda: build_general_program(4, 10, 6), ("A", "B"),
                     {"A": 0.25, "B": 0.3, "T0": 0.3}, 1e-6),
    "pagerank": (lambda: build_pagerank_program(10, k=4), ("M",),
                 {"M": 0.15}, 1e-6),
    "bgd": (lambda: build_bgd_program(16, 6, 1, k=4), ("X",),
            {"X": 0.5, "Y": 1.0, "Theta0": 0.1}, 1e-6),
    "ols": (lambda: build_ols_program(24, 6, 1), ("X",),
            {"X": 1.0, "Y": 1.0}, 2e-3),
}


def _gen_inputs(prog, rng, scales):
    from repro.core.cost import shape_of
    out = {}
    for name, v in prog.inputs.items():
        n, m = shape_of(v, dict(prog.dims))
        out[name] = (rng.standard_normal((n, m))
                     * scales.get(name, 0.3)).astype(np.float32)
    return out


def _ragged_stream(rng, shape, T):
    """T mixed-rank factored updates for one (n, m) input."""
    n, m = shape
    ups = []
    for _ in range(T):
        k = int(rng.integers(1, 3))
        ups.append(((rng.standard_normal((n, k)) * 0.02).astype(np.float32),
                    (rng.standard_normal((m, k)) * 0.02).astype(np.float32)))
    return ups


def _assert_views_match(eng, ref, tol, label=""):
    for stmt in eng.program.statements:
        name = stmt.target.name
        want = np.asarray(ref.views[name], np.float64)
        got = np.asarray(eng.views[name], np.float64)
        nrm = max(np.abs(want).max(), 1.0)
        diff = np.abs(got - want).max() / nrm
        assert diff <= tol, f"{label}{name}: {diff:.3e} > {tol}"


# ---------------------------------------------------------------------------
# the property suite: every app family × depth 1/2/3 × chaos-seed matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("family", sorted(FAMILIES))
@settings(max_examples=2, deadline=None)
@given(case=st.integers(min_value=0, max_value=2 ** 16),
       fold_window=st.sampled_from([2, 3]))
def test_depth_k_views_match_reevaluation(family, depth, seed, case,
                                          fold_window):
    build, upd_inputs, scales, tol = FAMILIES[family]
    prog = build()
    rng = np.random.default_rng((seed << 20) ^ case)
    inputs = _gen_inputs(prog, rng, scales)
    eng = IncrementalEngine(prog, order=depth, fold_window=fold_window)
    ref = ReevalEngine(prog)
    eng.initialize(inputs)
    ref.initialize(inputs)
    if depth >= 2:
        assert eng._deferred, "depth ≥ 2 must defer some view"
    shapes = {n: np.asarray(a).shape for n, a in inputs.items()}
    for _ in range(7):
        name = upd_inputs[int(rng.integers(len(upd_inputs)))]
        ups = _ragged_stream(rng, shapes[name], T=int(rng.integers(1, 4)))
        eng.apply_updates(name, ups)
        for u, v in ups:
            ref.apply_update(name, u, v)
    eng.flush()  # the read barrier: folds every pending window
    assert not eng._cascade_pending()
    _assert_views_match(eng, ref, tol, label=f"{family}@d{depth}: ")
    if depth >= 2:
        assert eng.stats.folds > 0


def test_reads_interleaved_with_stream_stay_exact():
    """output() mid-stream forces a fold of every tier and keeps serving
    exact values — the w_eff = min(w, 1/rho) story, numerically."""
    prog = build_sums_program(4, 10)
    rng = np.random.default_rng(3)
    inputs = _gen_inputs(prog, rng, {"A": 0.25})
    eng = IncrementalEngine(prog, order=3, fold_window=3)
    ref = ReevalEngine(prog)
    eng.initialize(inputs)
    ref.initialize(inputs)
    out = prog.output_names()[0]
    for i in range(10):
        ups = _ragged_stream(rng, (10, 10), T=1)
        eng.apply_updates("A", ups)
        ref.apply_update("A", *ups[0])
        if i % 4 == 1:  # read mid-window
            got = np.asarray(eng.output(out), np.float64)
            want = np.asarray(ref.views[out], np.float64)
            nrm = max(np.abs(want).max(), 1.0)
            assert np.abs(got - want).max() / nrm <= 1e-6
    assert eng.stats.reads >= 2


# ---------------------------------------------------------------------------
# the symbolic Δᵏ hierarchy (compiler layer)
# ---------------------------------------------------------------------------


def test_delta_view_registration_and_names():
    prog = build_general_program(4, 10, 6)
    c = compile_program(prog, order=2)
    assert c.order == 2
    assert delta_view_name("P2", 2) == "__d2__P2"
    reg = c.delta_views[("A", 2)]
    assert reg, "Δ² of the A-chain must register auxiliary views"
    for name, dv in reg.items():
        assert dv.view == name
        assert dv.name == delta_view_name(name, 2)
        assert dv.depth == 2 and dv.input_name == "A"
        assert dv.kind in ("lowrank", "dense")
        assert dv.flops >= 0.0
    # first-order compiles carry no hierarchy (regression pin)
    c1 = compile_program(prog)
    assert c1.order == 1 and not c1.delta_views


def test_delta_hierarchy_terminates_at_degree():
    """DBToaster termination: Δ^(d+1) ≡ 0 for a degree-d polynomial.
    matrix_powers k=4 is degree 4: depth 4 is the last non-zero level."""
    c = compile_program(build_powers_program(4, 8), order=5)
    assert c.delta_views[("A", 2)]
    assert c.delta_views[("A", 4)]
    assert not c.delta_views.get(("A", 5))


def test_inverse_unsupported_at_depth_two():
    c = compile_program(build_ols_program(20, 6, 1), order=2)
    # Z = XᵀX is quadratic: its Δ² exists; W = Z⁻¹ and beta do not
    assert "Z" in c.delta_views[("X", 2)]
    assert set(c.delta_unsupported[("X", 2)]) == {"W", "beta"}
    with pytest.raises(IncrementalInverseError):
        compile_delta_trigger(c, "X", 2)


def test_delta2_trigger_matches_second_difference(rng):
    """The materialized Δ² trigger against the numeric second
    difference: Δ²E(A; d, d) = E(A+2d) − 2E(A+d) + E(A)."""
    prog = build_powers_program(2, 8)  # single statement P2 = A·A
    A = (rng.standard_normal((8, 8)) * 0.3).astype(np.float32)
    eng = IncrementalEngine(prog, order=2)
    eng.initialize({"A": A})
    names = eng.materialize_delta_views("A", 2)
    assert names == ("__d2__P2",)
    fn = eng.delta_trigger_fn("A", 2)
    u = (rng.standard_normal((8, 1)) * 0.2).astype(np.float32)
    v = (rng.standard_normal((8, 1)) * 0.2).astype(np.float32)
    out = fn(dict(eng.views), u, v)
    d = u @ v.T

    def E(a):
        return a @ a

    expected = E(A + 2 * d) - 2 * E(A + d) + E(A)  # == 2·d·d
    np.testing.assert_allclose(np.asarray(out["__d2__P2"]), expected,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(expected, 2 * d @ d, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# the TriggerCache collision fix (satellite 4)
# ---------------------------------------------------------------------------


def test_trigger_cache_namespace_carries_order():
    """Regression: the shared-cache key used to omit the delta order, so
    an order-2 engine's deferred-lazy planned trigger could be served to
    a first-order engine of the same program (and vice versa)."""
    prog = build_powers_program(4, 12)
    cache = TriggerCache(capacity=64)
    e1 = IncrementalEngine(prog, trigger_cache=cache)
    e2 = IncrementalEngine(prog, order=2, fold_window=2,
                           trigger_cache=cache)
    tail = ("batched", "A", 1)
    assert e1._cache_key(tail) != e2._cache_key(tail)
    # depth-keyed delta tails are distinct per depth and memoized
    e3 = IncrementalEngine(prog, order=3, fold_window=2,
                           trigger_cache=cache)
    rng = np.random.default_rng(0)
    A = (rng.standard_normal((12, 12)) * 0.25).astype(np.float32)
    e3.initialize({"A": A})
    f2 = e3.delta_trigger_fn("A", 2)
    f3 = e3.delta_trigger_fn("A", 3)
    assert f2 is not f3
    assert e3.delta_trigger_fn("A", 2) is f2


def test_trigger_cache_concurrent_cross_order_engines():
    """Two same-program engines at different orders share one cache and
    are driven concurrently with identical streams; each must end
    bit-identical to an isolated engine of its own order — a colliding
    key would hand one engine the other's compiled trigger."""
    prog = build_sums_program(4, 10)
    rng = np.random.default_rng(7)
    inputs = _gen_inputs(prog, rng, {"A": 0.25})
    stream = [_ragged_stream(rng, (10, 10), T=2) for _ in range(6)]
    cache = TriggerCache(capacity=64)
    orders = [None, 2]
    shared = [IncrementalEngine(prog, order=o, fold_window=2,
                                trigger_cache=cache) for o in orders]
    isolated = [IncrementalEngine(prog, order=o, fold_window=2)
                for o in orders]
    for e in shared + isolated:
        e.initialize(inputs)
    errors = []

    def drive(eng):
        try:
            for ups in stream:
                eng.apply_updates("A", ups)
            eng.flush()
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=drive, args=(e,)) for e in shared]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for e in isolated:
        drive(e)
    for e_shared, e_iso in zip(shared, isolated):
        assert max_abs_diff(e_shared.views, e_iso.views) == 0.0
    assert cache.stats()["entries"] >= 2  # one namespace per order


# ---------------------------------------------------------------------------
# planner depth pricing + the adaptive reads-per-firing fit
# ---------------------------------------------------------------------------


def _past_crossover_setting():
    """A cell where first-order maintenance loses to re-evaluation:
    n=12 views with stacked update rank 16 > n (the §7 crossover)."""
    prog = build_powers_program(4, 12)
    wl = WorkloadDescriptor(update_rank=4, batch_size=4,
                            rank_lo=8, rank_hi=24)
    return prog, wl


def test_viewplan_order_validation():
    with pytest.raises(ValueError):
        ViewPlan(view="V", strategy="incremental", order=0)


def test_plan_program_prices_depth_only_when_it_pays():
    prog, wl = _past_crossover_setting()
    compiled = compile_program(prog)
    # dense reads (the default rho=1.0): a read folds every window, so
    # depth never amortizes and the plan must stay first-order
    base = plan_program(compiled, replace(wl, max_order=3))
    assert all(vp.order == 1 for vp in base.views.values())
    # sparse reads past the crossover: depth ≥ 2 wins ≥ 2×
    deep = plan_program(compiled, replace(wl, max_order=3, fold_window=8,
                                          reads_per_firing=0.02))
    orders = {n: vp.order for n, vp in deep.views.items()}
    assert any(o >= 2 for o in orders.values()), orders
    assert all(vp.materialize for vp in deep.views.values())
    # max_order=1 is inert regardless of read sparsity (regression pin)
    flat = plan_program(compiled, replace(wl, reads_per_firing=0.02))
    assert all(vp.order == 1 for vp in flat.views.values())


def test_plan_depth_respects_producer_consumer_monotonicity():
    prog, wl = _past_crossover_setting()
    deep = plan_program(compile_program(prog),
                        replace(wl, max_order=3, fold_window=8,
                                reads_per_firing=0.02))
    orders = {n: vp.order for n, vp in deep.views.items()}
    consumers = {}
    names = set(orders)
    for stmt in prog.statements:
        for dep in stmt.expr.free_vars():
            if dep in names and dep != stmt.target.name:
                consumers.setdefault(dep, []).append(stmt.target.name)
    for name, cs in consumers.items():
        for c in cs:
            assert orders[name] <= orders[c], \
                f"producer {name} (d{orders[name]}) staler than " \
                f"consumer {c} (d{orders[c]})"


def test_adaptive_planner_fits_reads_per_firing():
    prog, _ = _past_crossover_setting()
    compiled = compile_program(prog)
    wl = WorkloadDescriptor(update_rank=1, max_order=2, fold_window=8)
    ap = AdaptivePlanner(wl, replan_every=8, drift_tol=0.3)
    ap.bind(compiled)
    assert all(vp.order == 1 for vp in ap.plan.views.values())
    for _ in range(40):
        ap.observe("A", 16, 4)
    ap.observe_read()
    ap.observe_read()
    fitted = ap.observed_workload()
    assert fitted.reads_per_firing == pytest.approx(2 / 40)
    new = ap.maybe_replan()
    assert new is not None
    assert any(vp.order >= 2 for vp in new.views.values())
    # without the max_order opt-in the fit never touches the ratio
    ap1 = AdaptivePlanner(WorkloadDescriptor(update_rank=1), replan_every=8)
    ap1.bind(compiled)
    for _ in range(10):
        ap1.observe("A", 16, 4)
    ap1.observe_read()
    assert ap1.observed_workload().reads_per_firing == 1.0


def test_engine_adopts_plan_depth_and_stays_exact():
    prog = build_powers_program(4, 12)
    compiled = compile_program(prog)
    base = plan_program(compiled, WorkloadDescriptor(update_rank=1))
    deep = replace(base, views={n: replace(vp, strategy="incremental",
                                           threshold_rank=None,
                                           materialize=True, order=2)
                                for n, vp in base.views.items()})
    rng = np.random.default_rng(11)
    inputs = _gen_inputs(prog, rng, {"A": 0.25})
    eng = IncrementalEngine(prog, plan=deep, fold_window=3)
    ref = ReevalEngine(prog)
    eng.initialize(inputs)
    ref.initialize(inputs)
    assert set(eng._deferred) == set(base.views)
    for _ in range(8):
        ups = _ragged_stream(rng, (12, 12), T=2)
        eng.apply_updates("A", ups)
        for u, v in ups:
            ref.apply_update("A", u, v)
    eng.flush()
    _assert_views_match(eng, ref, 1e-6, label="planned-d2: ")
    assert eng.stats.folds > 0


def test_engine_rejects_lazy_plus_deferred_plan():
    prog = build_powers_program(4, 12)
    compiled = compile_program(prog)
    base = plan_program(compiled, WorkloadDescriptor(update_rank=1))
    views = dict(base.views)
    names = sorted(views)
    views[names[0]] = replace(views[names[0]], materialize=False)
    views[names[-1]] = replace(views[names[-1]], order=2,
                               materialize=True)
    bad = replace(base, views=views)
    with pytest.raises(ValueError, match="materialize"):
        IncrementalEngine(prog, plan=bad)


def test_engine_adaptive_depth_hot_swap_stays_exact():
    """End to end: sparse reads observed online tip the adaptive planner
    into a depth ≥ 2 plan; the engine hot-swaps it mid-stream (folding
    the old windows first) and keeps serving exact reads."""
    prog = build_powers_program(4, 12)
    wl = WorkloadDescriptor(update_rank=1, max_order=2, fold_window=4)
    eng = IncrementalEngine(
        prog, {"A": 4},
        plan=AdaptivePlanner(wl, replan_every=6, drift_tol=0.2),
        fold_window=4)
    ref = ReevalEngine(prog)
    rng = np.random.default_rng(13)
    inputs = _gen_inputs(prog, rng, {"A": 0.25})
    eng.initialize(inputs)
    ref.initialize(inputs)
    for _ in range(20):
        ups = [_ragged_stream(rng, (12, 12), T=1)[0] for _ in range(4)]
        ups = [(np.hstack([u for u, _ in ups]),
                np.hstack([v for _, v in ups]))]
        eng.apply_updates("A", ups)
        for u, v in ups:
            ref.apply_update("A", u, v)
    assert any(o >= 2 for o in eng._view_orders.values()), \
        "sparse-read workload past the crossover must adopt depth"
    out = prog.output_names()[0]
    got = np.asarray(eng.output(out), np.float64)
    want = np.asarray(ref.views[out], np.float64)
    assert np.abs(got - want).max() / max(np.abs(want).max(), 1.0) <= 1e-6


# ---------------------------------------------------------------------------
# fleet-facing pricing
# ---------------------------------------------------------------------------


def test_firing_cost_amortized_for_deferred_views():
    prog = build_powers_program(4, 16)
    compiled = compile_program(prog)
    binding = dict(prog.dims)
    wl = WorkloadDescriptor(max_order=3, fold_window=8)
    full = firing_cost_flops(compiled, binding, "A", 8, workload=wl)
    orders2 = {stmt.target.name: 2 for stmt in prog.statements}
    amort2 = firing_cost_flops(compiled, binding, "A", 8, workload=wl,
                               view_orders=orders2)
    orders3 = {stmt.target.name: 3 for stmt in prog.statements}
    amort3 = firing_cost_flops(compiled, binding, "A", 8, workload=wl,
                               view_orders=orders3)
    assert amort2 < full
    assert amort3 <= amort2
    # first-order signature is the identity (regression pin)
    assert firing_cost_flops(compiled, binding, "A", 8, workload=wl,
                             view_orders={}) == full


# ---------------------------------------------------------------------------
# carrier × higher-order interplay (ISSUE 10 satellite: the gap left by
# PR 8 and PR 9 landing independently)
# ---------------------------------------------------------------------------
#
# Deferred (order>=2) engines bank firings in factored form and fold at
# reads; sparsity-aware carriers arrive as RowLocal/NoOp objects.  The
# contract where they meet: a no-op carrier is skipped without touching
# the window, a row-local carrier WIDENS into the banked window (the
# fold sweeps from a base snapshot, so there is no row-slab fast path
# at depth >= 2 — `_rowlocal_ok` refuses deferred engines) — and both
# must leave the folded views exact against re-evaluation.


def _carrier_chain_prog(n=48, m=24, k=12):
    from repro.core import Program, dim, matmul
    p = Program(name="ho_carrier_chain")
    X = p.input("X", (dim("N"), dim("M")))
    W1 = p.input("W1", (dim("M"), dim("K")))
    Y1 = p.let("Y1", matmul(X, W1))
    p.let("Y2", matmul(Y1, p.input("W2", (dim("K"), dim("K")))))
    p.outputs = ["Y1", "Y2"]
    return p.bind_dims(N=n, M=m, K=k)


def _carrier_chain_inputs(seed, n=48, m=24, k=12):
    rng = np.random.default_rng(seed)
    return {"X": rng.standard_normal((n, m)).astype(np.float32) * 0.3,
            "W1": rng.standard_normal((m, k)).astype(np.float32) * 0.3,
            "W2": rng.standard_normal((k, k)).astype(np.float32) * 0.3}


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_rowlocal_carriers_through_order2_engine(seed):
    from repro.data import row_local_stream
    prog = _carrier_chain_prog()
    inputs = _carrier_chain_inputs(seed)
    lazy = IncrementalEngine(prog, {"X": 4}, order=2, fold_window=3)
    eager = IncrementalEngine(prog, {"X": 4})
    ref = ReevalEngine(prog)
    for e in (lazy, eager, ref):
        e.initialize(dict(inputs))
    stream = row_local_stream(48, 3, m=24, rank=2, seed=seed + 1)
    for c in [stream.next_carrier() for _ in range(10)]:
        lazy.apply_update("X", c)
        eager.apply_update("X", c)
        P, Q = c.factors()
        ref.apply_update("X", P, Q)
    lazy.output()
    # the eager engine fired row-slabs (Y1/Y2 are row-local); the lazy
    # one banked and folded — carriers widen at depth >= 2 by contract
    assert eager.stats.rowlocal_firings == 10
    assert lazy.stats.rowlocal_firings == 0
    assert lazy.stats.folds > 0
    for name in ("Y1", "Y2"):
        a = np.asarray(lazy.views[name], np.float64)
        b = np.asarray(ref.views[name], np.float64)
        assert np.abs(a - b).max() / max(np.abs(b).max(), 1.0) < 1e-5


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_noop_carriers_through_order2_engine(seed):
    from repro.core import NoOpCarrier
    prog = _carrier_chain_prog()
    lazy = IncrementalEngine(prog, {"X": 4}, order=2, fold_window=3)
    lazy.initialize(_carrier_chain_inputs(seed))
    before = {k: np.asarray(v).copy() for k, v in lazy.views.items()}
    for _ in range(7):
        lazy.apply_update("X", NoOpCarrier(48, 24))
    lazy.output()
    assert lazy.stats.noop_skips == 7
    # no-ops never enter the window: nothing banked, nothing folded in
    for name in ("Y1", "Y2"):
        assert np.array_equal(np.asarray(lazy.views[name]), before[name])


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_mixed_carriers_and_dense_through_order2(seed):
    """Interleaved RowLocal / LowRank / dense / NoOp updates through a
    depth-2 window must fold to the re-evaluation answer."""
    from repro.core import LowRankCarrier, NoOpCarrier
    from repro.data import row_local_stream
    rng = np.random.default_rng(seed + 5)
    prog = _carrier_chain_prog()
    inputs = _carrier_chain_inputs(seed)
    lazy = IncrementalEngine(prog, {"X": 4}, order=2, fold_window=2)
    ref = ReevalEngine(prog)
    lazy.initialize(dict(inputs))
    ref.initialize(dict(inputs))
    stream = row_local_stream(48, 2, m=24, rank=2, seed=seed)
    for step in range(12):
        kind = step % 4
        if kind == 0:
            c = stream.next_carrier()
            lazy.apply_update("X", c)
            P, Q = c.factors()
            ref.apply_update("X", P, Q)
        elif kind == 1:
            P = (rng.standard_normal((48, 2)) * 0.1).astype(np.float32)
            Q = (rng.standard_normal((24, 2)) * 0.1).astype(np.float32)
            lazy.apply_update("X", LowRankCarrier(P, Q))
            ref.apply_update("X", P, Q)
        elif kind == 2:
            u = (rng.standard_normal((48, 4)) * 0.1).astype(np.float32)
            v = (rng.standard_normal((24, 4)) * 0.1).astype(np.float32)
            lazy.apply_update("X", u, v)
            ref.apply_update("X", u, v)
        else:
            lazy.apply_update("X", NoOpCarrier(48, 24))
    lazy.output()
    for name in ("Y1", "Y2"):
        a = np.asarray(lazy.views[name], np.float64)
        b = np.asarray(ref.views[name], np.float64)
        assert np.abs(a - b).max() / max(np.abs(b).max(), 1.0) < 1e-5
    assert lazy.stats.folds > 0 and lazy.stats.noop_skips == 3
