"""Sparsity-aware delta carrier tests (row-local containment).

The oracle chain is three engines fed the *same* logical updates:
  row-local carriers (row-slab triggers)  ≡  dense factor pairs
  (rank-k sweeps)  ≡  full re-evaluation — the dense path is the
bit-stable reference the carrier path must agree with to kernel
tolerance, and re-evaluation pins both to the paper's semantics.

Also here: carrier widening at closure boundaries (§4 product-rule
support analysis), the guard's no-op gate soundness bound, fleet replay
bit-identity with mixed-carrier tenants under chaos (REPRO_CHAOS_SEEDS,
comma-separated; default "0" locally, a matrix in CI), the one-time
CPU buffer-donation capability warning, and seeded determinism of the
carrier-native update streams.
"""

import os
import warnings

import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is not installed in this container")

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (IncrementalEngine, LowRankCarrier, NoOpCarrier,
                        Program, ReevalEngine, RowLocalCarrier, as_carrier,
                        compile_program, detect_row_local, dim, matmul,
                        max_abs_diff, stack_carriers, transpose)
from repro.data import RowLocalStream, row_local_stream, zipf_row_stream
from repro.fleet import ADMITTED, FleetConfig, FleetScheduler, TenantSpec
from repro.guard import ChaosConfig, GuardConfig
from repro.guard.validate import ValidationPolicy

from conftest import assert_close

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("REPRO_CHAOS_SEEDS", "0").split(",")]

seeds = st.integers(min_value=0, max_value=2 ** 16)


def _chain_prog(n=64, m=32, k=16):
    """Left chain X·W1·W2 — row-locality of ΔX closes through both
    views (the carrier stays "row_local" end to end)."""
    p = Program(name="chain")
    X = p.input("X", (dim("N"), dim("M")))
    W1 = p.input("W1", (dim("M"), dim("K")))
    W2 = p.input("W2", (dim("K"), dim("K")))
    Y1 = p.let("Y1", matmul(X, W1))
    p.let("Y2", matmul(Y1, W2))
    p.outputs = ["Y1", "Y2"]
    return p.bind_dims(N=n, M=m, K=k)


def _gram_prog(n=48, m=16):
    """Gram matrix XᵀX — the transpose breaks row-support preservation,
    so a row-local ΔX must widen at this view."""
    p = Program(name="gram")
    X = p.input("X", (dim("N"), dim("M")))
    p.let("G", matmul(transpose(X), X))
    p.outputs = ["G"]
    return p.bind_dims(N=n, M=m)


def _chain_inputs(seed, n=64, m=32, k=16):
    rng = np.random.default_rng(seed)
    return {"X": rng.standard_normal((n, m)).astype(np.float32),
            "W1": rng.standard_normal((m, k)).astype(np.float32),
            "W2": rng.standard_normal((k, k)).astype(np.float32)}


# ---------------------------------------------------------------------------
# P1: row-local ≡ dense ≡ re-evaluation under ragged carrier streams
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=seeds,
       steps=st.integers(min_value=1, max_value=4),
       rank=st.integers(min_value=1, max_value=3),
       rows_touched=st.integers(min_value=1, max_value=6))
def test_rowlocal_equals_dense_equals_reeval(seed, steps, rank,
                                             rows_touched):
    prog = _chain_prog()
    inputs = _chain_inputs(seed)
    carrier_eng = IncrementalEngine(prog, {"X": rank})
    dense_eng = IncrementalEngine(prog, {"X": rank})
    ree = ReevalEngine(prog)
    for e in (carrier_eng, dense_eng, ree):
        e.initialize(inputs)
    stream = row_local_stream(64, rows_touched, m=32, rank=rank, seed=seed)
    for _ in range(steps):
        c = stream.next_carrier()
        carrier_eng.apply_update("X", c)
        P, Q = c.factors()
        dense_eng.apply_update("X", P, Q)
        ree.apply_update("X", P, Q)
    for name in ("Y1", "Y2"):
        assert_close(carrier_eng.views[name], dense_eng.views[name],
                     msg=f"carrier vs dense on {name}")
        assert_close(carrier_eng.views[name], ree.views[name],
                     msg=f"carrier vs reeval on {name}")
    # the carrier path actually exercised the row-slab triggers
    assert carrier_eng.stats.rowlocal_firings == steps
    assert carrier_eng.stats.widened_carriers == 0


@settings(max_examples=10, deadline=None)
@given(seed=seeds, batches=st.integers(min_value=1, max_value=3))
def test_ragged_mixed_carrier_batches_match_dense(seed, batches):
    """Ragged batches mixing row-local / low-rank / no-op / raw pairs
    through apply_updates agree with the dense batch path."""
    prog = _chain_prog()
    inputs = _chain_inputs(seed)
    a = IncrementalEngine(prog, {"X": 2})
    b = IncrementalEngine(prog, {"X": 2})
    a.initialize(inputs)
    b.initialize(inputs)
    rng = np.random.default_rng(seed + 1)
    stream = row_local_stream(64, 3, m=32, rank=2, seed=seed)
    for _ in range(batches):
        rl = stream.next_carrier()
        P = (0.1 * rng.standard_normal((64, 2))).astype(np.float32)
        Q = (0.1 * rng.standard_normal((32, 2))).astype(np.float32)
        u = (0.1 * rng.standard_normal((64, 1))).astype(np.float32)
        v = (0.1 * rng.standard_normal((32, 1))).astype(np.float32)
        mixed = [rl, LowRankCarrier(P, Q), NoOpCarrier(64, 32), (u, v)]
        a.apply_updates("X", mixed)
        dense = [rl.factors(), (P, Q), (u, v)]   # noop contributes nothing
        b.apply_updates("X", dense)
    assert a.stats.noop_skips == batches
    for name in ("Y1", "Y2"):
        assert_close(a.views[name], b.views[name], msg=name)


def test_pure_rowlocal_batch_fires_row_slab_once():
    prog = _chain_prog()
    eng = IncrementalEngine(prog, {"X": 2})
    eng.initialize(_chain_inputs(3))
    ree = ReevalEngine(prog)
    ree.initialize(_chain_inputs(3))
    stream = row_local_stream(64, 2, m=32, rank=2, seed=5)
    cs = [stream.next_carrier() for _ in range(4)]
    eng.apply_updates("X", cs)
    for c in cs:
        ree.apply_update("X", *c.factors())
    assert eng.stats.rowlocal_firings == 1      # one stacked firing
    assert eng.stats.updates_applied == 4       # four logical updates
    for name in ("Y1", "Y2"):
        assert_close(eng.views[name], ree.views[name], msg=name)


# ---------------------------------------------------------------------------
# carrier widening at closure boundaries
# ---------------------------------------------------------------------------

def test_compiler_carrier_kinds_chain_vs_gram():
    chain = compile_program(_chain_prog())
    kinds = chain.triggers["X"].carriers
    assert kinds["Y1"] == "row_local" and kinds["Y2"] == "row_local"
    gram = compile_program(_gram_prog())
    assert gram.triggers["X"].carriers["G"] != "row_local"


def test_rowlocal_carrier_widens_at_gram_and_stays_exact():
    prog = _gram_prog()
    rng = np.random.default_rng(0)
    X0 = rng.standard_normal((48, 16)).astype(np.float32)
    eng = IncrementalEngine(prog, {"X": 2})
    eng.initialize({"X": X0})
    ree = ReevalEngine(prog)
    ree.initialize({"X": X0})
    c = row_local_stream(48, 3, m=16, rank=2, seed=1).next_carrier()
    eng.apply_update("X", c)
    ree.apply_update("X", *c.factors())
    assert eng.stats.widened_carriers == 1      # closure boundary hit
    assert eng.stats.rowlocal_firings == 0
    assert_close(eng.views["G"], ree.views["G"])


@settings(max_examples=15, deadline=None)
@given(seed=seeds, r=st.integers(min_value=1, max_value=8))
def test_detect_and_stack_preserve_dense_equivalence(seed, r):
    rng = np.random.default_rng(seed)
    n, m = 32, 12
    rows = np.sort(rng.choice(n, size=r, replace=False)).astype(np.int32)
    u = np.zeros((n, 2), dtype=np.float32)
    u[rows] = rng.standard_normal((r, 2)).astype(np.float32)
    v = rng.standard_normal((m, 2)).astype(np.float32)
    c = detect_row_local(u, v)
    assert c.kind == "row_local" and np.array_equal(np.sort(rows), c.rows)
    P, Q = c.factors()
    assert_close(P @ Q.T, u @ v.T)
    # stacking two contained carriers keeps the union support compact
    c2 = row_local_stream(n, 2, m=m, rank=1, seed=seed).next_carrier()
    s = stack_carriers([c, c2])
    assert s.kind == "row_local"
    P1, Q1 = c2.factors()
    Ps, Qs = s.factors()
    assert_close(Ps @ Qs.T, u @ v.T + P1 @ Q1.T)
    # a dense member forces the stack to widen — correctly
    d = as_carrier((0.1 * rng.standard_normal((n, 1))).astype(np.float32),
                   (0.1 * rng.standard_normal((m, 1))).astype(np.float32))
    w = stack_carriers([c, d])
    assert w.kind != "row_local"
    Pw, Qw = w.factors()
    Pd, Qd = d.factors()
    assert_close(Pw @ Qw.T, u @ v.T + Pd @ Qd.T)


# ---------------------------------------------------------------------------
# guard no-op gate: soundness (never skips a real delta)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=seeds, scale=st.floats(min_value=1e-9, max_value=1e-2))
def test_noop_gate_never_skips_above_tolerance(seed, scale):
    """The gate skips on the bound ‖u‖·‖v‖ ≥ ‖uvᵀ‖_F, so every skipped
    update's *true* delta is ≤ noop_tol — and every non-skipped update
    must land in the views."""
    tol = 1e-4
    prog = _chain_prog()
    eng = IncrementalEngine(
        prog, {"X": 1},
        guard=GuardConfig(validation=ValidationPolicy(noop_tol=tol)))
    eng.initialize(_chain_inputs(seed))
    rng = np.random.default_rng(seed)
    u = (scale * rng.standard_normal((64, 1))).astype(np.float32)
    v = (scale * rng.standard_normal((32, 1))).astype(np.float32)
    before = {k: np.asarray(val) for k, val in eng.views.items()}
    skips0 = eng.guard.stats.noop_skips
    eng.apply_update("X", u, v)
    if eng.guard.stats.noop_skips > skips0:
        # soundness: the skipped delta could not have moved any view
        # past tol (linear views contract through bounded factors here,
        # but the raw-input bound is the one the gate promises)
        assert float(np.linalg.norm(u @ v.T)) <= tol
        assert max_abs_diff(eng.views, before) == 0.0
    else:
        assert np.asarray(eng.views["X"]) is not before["X"]


def test_noop_gate_on_rowlocal_carrier_and_nan_falls_through():
    tol = 1e-6
    prog = _chain_prog()
    eng = IncrementalEngine(
        prog, {"X": 2},
        guard=GuardConfig(validation=ValidationPolicy(noop_tol=tol)))
    eng.initialize(_chain_inputs(0))
    tiny = row_local_stream(64, 2, m=32, rank=2, scale=1e-8,
                            seed=0).next_carrier()
    before = {k: np.asarray(v) for k, v in eng.views.items()}
    eng.apply_update("X", tiny)
    assert eng.guard.stats.noop_skips == 1
    assert eng.guard.stats.quarantined == 0      # a no-op is not a fault
    assert max_abs_diff(eng.views, before) == 0.0
    # NaN norms fail the ≤ comparison: a poisoned tiny update is
    # quarantined, never silently skipped
    bad = row_local_stream(64, 2, m=32, rank=2, scale=1e-8,
                           seed=1).next_carrier()
    bad.block[0, 0] = np.nan
    eng.apply_update("X", bad)
    assert eng.guard.stats.noop_skips == 1       # unchanged
    assert eng.guard.stats.quarantined == 1
    assert max_abs_diff(eng.views, before) == 0.0


# ---------------------------------------------------------------------------
# fleet: mixed-carrier tenants, replay bit-identity under chaos
# ---------------------------------------------------------------------------

def _replay_reference(tenant, inputs, payload_by_lsn):
    ref = IncrementalEngine(tenant.spec.program, tenant.spec.update_ranks,
                            guard=tenant.spec.guarded or None)
    ref.initialize(inputs)
    for input_name, lsns in tenant.commit_log:
        assert input_name != "<reeval>", "property test must not degrade"
        ref.apply_updates(input_name, [payload_by_lsn[l] for l in lsns])
    return ref


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fleet_mixed_carrier_replay_bit_identical(seed):
    """Tenants fed an interleaved mix of row-local carriers, low-rank
    carriers, raw pairs, and no-ops, under worker crashes + lease
    expiry + poison: committed stores are bit-identical to isolated
    engines replaying each tenant's committed groups (the logged —
    post-poisoning — payloads, in the same representation)."""
    import time as _time

    class VClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

        def sleep(self, dt):
            self.t += dt

    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    chaos=ChaosConfig(seed=seed, worker_crash_p=0.15,
                                      lease_expiry_p=0.15, poison_p=0.05)),
        clock=vc, sleep=vc.sleep)
    tenant_inputs = {}
    for i in range(2):
        tid = f"t{i}"
        tenant_inputs[tid] = _chain_inputs(seed + i)
        fleet.add_tenant(
            TenantSpec(tid, _chain_prog(), {"X": 2}, max_claim_rank=6),
            tenant_inputs[tid])
    rng = np.random.default_rng(seed + 9)
    streams = {tid: row_local_stream(64, 3, m=32, rank=2,
                                     seed=seed + 50 + i)
               for i, tid in enumerate(sorted(tenant_inputs))}
    by_lsn = {tid: {} for tid in tenant_inputs}
    admitted = {tid: 0 for tid in tenant_inputs}
    noops = 0
    for step in range(60):
        tid = f"t{rng.integers(2)}"
        kind = int(rng.integers(4))
        if kind == 0:
            sub = (streams[tid].next_carrier(),)
        elif kind == 1:
            sub = (LowRankCarrier(
                (0.1 * rng.standard_normal((64, 2))).astype(np.float32),
                (0.1 * rng.standard_normal((32, 2))).astype(np.float32)),)
        elif kind == 2:
            sub = ((0.1 * rng.standard_normal((64, 1))).astype(np.float32),
                   (0.1 * rng.standard_normal((32, 1))).astype(np.float32))
        else:
            sub = (NoOpCarrier(64, 32),)
            noops += 1
        assert fleet.submit(tid, "X", *sub) == ADMITTED
        tenant = fleet.registry.get(tid)
        if len(sub) == 1 and sub[0].kind == "noop":
            continue                    # acked, never logged
        admitted[tid] += 1
        entry = tenant.log.pending(0)[-1]
        by_lsn[tid][entry.lsn] = entry.payload()   # post-poisoning
        vc.sleep(0.01)
        if step % 15 == 14:
            fleet.run_until_idle(workers=2,
                                 on_stall=lambda: vc.sleep(1.1))
    fleet.run_until_idle(workers=2, on_stall=lambda: vc.sleep(1.1))
    total_noop_skips = 0
    for tid in sorted(tenant_inputs):
        tenant = fleet.registry.get(tid)
        assert not tenant.dirty()
        assert tenant.stats.committed_updates == admitted[tid]
        total_noop_skips += tenant.stats.noop_skips
        ref = _replay_reference(tenant, tenant_inputs[tid], by_lsn[tid])
        assert max_abs_diff(tenant.committed_views, ref.views) == 0.0, tid
        # every committed view stayed finite despite the poison stream
        for val in tenant.committed_views.values():
            assert np.isfinite(np.asarray(val)).all()
    assert total_noop_skips == noops
    assert fleet.chaos.worker_crashes + fleet.chaos.lease_expiries > 0


# ---------------------------------------------------------------------------
# codegen: one-time CPU donation capability warning
# ---------------------------------------------------------------------------

def test_donation_warning_fires_exactly_once():
    import jax

    from repro.core import codegen
    from repro.core.codegen import build_trigger_fn

    if jax.default_backend() != "cpu":
        pytest.skip("capability warning is CPU-only")
    compiled = compile_program(_chain_prog())
    trig = compiled.triggers["X"]
    old = codegen._donation_warned
    try:
        codegen._donation_warned = False
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            build_trigger_fn(trig, compiled.program, donate=True)
            build_trigger_fn(trig, compiled.program, donate=True)
        donation = [w for w in caught
                    if issubclass(w.category, RuntimeWarning)
                    and "donation" in str(w.message)]
        assert len(donation) == 1, "warning must fire exactly once"
        assert "CPU" in str(donation[0].message)
        # donate=False never warns
        codegen._donation_warned = False
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            build_trigger_fn(trig, compiled.program, donate=False)
        assert not [w for w in caught
                    if "donation" in str(w.message)]
    finally:
        codegen._donation_warned = old


# ---------------------------------------------------------------------------
# data: carrier-native streams are seeded-deterministic
# ---------------------------------------------------------------------------

def test_row_local_stream_seeded_determinism():
    mk = lambda: row_local_stream(128, 4, m=32, rank=2, seed=7)
    s1, s2 = mk(), mk()
    draws1 = [s1.next_carrier() for _ in range(6)]
    draws2 = [s2.next_carrier() for _ in range(6)]
    for a, b in zip(draws1, draws2):
        assert np.array_equal(a.rows, b.rows)
        assert np.array_equal(a.block, b.block)
        assert np.array_equal(a.V, b.V)
    # draws advance shared state (no silent per-call re-seeding) …
    assert not np.array_equal(draws1[0].block, draws1[1].block)
    # … and reset() replays from the seed
    s1.reset()
    c = s1.next_carrier()
    assert np.array_equal(c.rows, draws1[0].rows)
    assert np.array_equal(c.block, draws1[0].block)


def test_zipf_row_stream_carrier_native():
    z = zipf_row_stream(128, 32, 1.5, seed=3, rows_touched=6)
    assert isinstance(z, RowLocalStream)
    c = z.next_carrier()
    assert c.kind == "row_local"
    assert np.all(np.diff(c.rows) > 0)          # sorted, deduped
    assert 1 <= len(c.rows) <= 6                # skew may collapse rows
    assert c.n == 128 and c.V.shape[0] == 32
    # legacy form unchanged without rows_touched
    legacy = zipf_row_stream(128, 32, 1.5, seed=3)
    u, v = legacy.next_update()
    assert u.shape == (128, 1) and v.shape == (32, 1)


def test_stream_batch_is_dense_equivalent():
    s = row_local_stream(64, 3, m=16, rank=1, seed=11)
    probe = row_local_stream(64, 3, m=16, rank=1, seed=11)
    stacked = s.batch(5)
    dense = np.zeros((64, 16), dtype=np.float64)
    for _ in range(5):
        c = probe.next_carrier()
        P, Q = c.factors()
        dense += (P @ Q.T).astype(np.float64)
    Ps, Qs = stacked.factors()
    assert_close(Ps @ Qs.T, dense)


# ---------------------------------------------------------------------------
# P9: compact-chain analysis and the in-place CPU apply
# ---------------------------------------------------------------------------

def test_compact_chain_names_chain_vs_gram():
    from repro.core.codegen import compact_chain_names
    chain = compile_program(_chain_prog()).triggers["X"]
    names = compact_chain_names(chain)
    # every left factor in the chain stays compact (dU_X and the
    # per-view left blocks that alias it)
    assert names is not None and chain.u_var.name in names
    gram = compile_program(_gram_prog()).triggers["X"]
    # ΔG references ΔXᵀ — the chain cannot run compactly
    assert compact_chain_names(gram) is None


def test_inplace_apply_matches_staged_and_mutates_in_place():
    n, m, k = 96, 24, 12
    inputs = _chain_inputs(5, n, m, k)
    auto = IncrementalEngine(_chain_prog(n, m, k), {"X": 2})
    staged = IncrementalEngine(_chain_prog(n, m, k), {"X": 2},
                               rowlocal_apply="jit")
    auto.initialize(inputs)
    staged.initialize(inputs)
    s = row_local_stream(n, 3, m=m, rank=2, scale=0.1, seed=7)
    probe = row_local_stream(n, 3, m=m, rank=2, scale=0.1, seed=7)
    for _ in range(6):
        auto.apply_update("X", s.next_carrier())
        staged.apply_update("X", probe.next_carrier())
    # on the CPU backend "auto" engages the in-place path: the written
    # views live on mutable np storage and later firings reuse it
    assert isinstance(auto.views["Y2"], np.ndarray)
    assert not isinstance(staged.views["Y2"], np.ndarray)
    assert auto.stats.rowlocal_firings == 6
    assert staged.stats.rowlocal_firings == 6
    for name in ("X", "Y1", "Y2"):
        assert_close(np.asarray(auto.views[name]),
                     np.asarray(staged.views[name]), atol=1e-4)
    # a dense firing after in-place firings re-ingests np views exactly
    rng = np.random.default_rng(8)
    u = (0.1 * rng.standard_normal((n, 2))).astype(np.float32)
    v = (0.1 * rng.standard_normal((m, 2))).astype(np.float32)
    auto.apply_update("X", u, v)
    staged.apply_update("X", u, v)
    assert_close(np.asarray(auto.views["Y2"]),
                 np.asarray(staged.views["Y2"]), atol=1e-4)


def test_guarded_engine_keeps_staged_rowlocal_path():
    n, m, k = 96, 24, 12
    inputs = _chain_inputs(6, n, m, k)
    eng = IncrementalEngine(_chain_prog(n, m, k), {"X": 1}, guard=True)
    eng.initialize(inputs)
    s = row_local_stream(n, 3, m=m, rank=1, scale=0.1, seed=9)
    for _ in range(3):
        eng.apply_update("X", s.next_carrier())
    assert eng.stats.rowlocal_firings == 3
    # the transactional guard needs copy-on-write firings: views must
    # never be switched to mutable in-place storage
    assert not isinstance(eng.views["Y2"], np.ndarray)


def test_contained_high_rank_batch_prices_at_scaled_rank():
    """A stacked contained batch whose rank crosses the §7 crossover
    must NOT be kicked to re-evaluation at the full-rank price: a
    row-slab sweep touches r·m elements, so the decision is priced at
    ceil(rank·frac) (the planner's K*/frac scaling, engine-side)."""
    n, m, k = 2048, 96, 64
    inputs = _chain_inputs(7, n, m, k)
    eng = IncrementalEngine(_chain_prog(n, m, k), {"X": 1},
                            flush_policy="cost")
    eng.initialize(inputs)
    ref = IncrementalEngine(_chain_prog(n, m, k), {"X": 1})
    ref.initialize(inputs)
    s = row_local_stream(n, 2, m=m, rank=1, scale=0.05, seed=3)
    batch = [s.next_carrier() for _ in range(96)]
    stacked = stack_carriers(batch)
    # the full-rank price would re-evaluate Y2 (rank 96 >= K* = 64)...
    assert eng._plan_decision("X", stacked.rank) != (frozenset(),
                                                     frozenset())
    # ...but the contained batch still fires the row-slab path
    assert eng._rowlocal_ok("X", stacked)
    eng.apply_updates("X", batch)
    assert eng.stats.rowlocal_firings == 1
    assert eng.stats.widened_carriers == 0
    ref.apply_updates("X", [c.factors() for c in batch])
    assert_close(np.asarray(eng.views["Y2"]), np.asarray(ref.views["Y2"]),
                 atol=5e-3)
