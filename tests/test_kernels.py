"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
in interpret mode (kernel bodies execute on CPU)."""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis is not installed in this container")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.rank_update import rank_update_pallas
from repro.kernels.dual_matmul import dual_matmul_pallas

from conftest import assert_close


@pytest.mark.parametrize("n,p,k", [
    (64, 64, 1), (128, 64, 2), (64, 128, 4), (256, 256, 8),
    (96, 160, 3), (8, 8, 1), (512, 64, 16),
])
def test_rank_update_shapes(n, p, k, rng):
    m = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, k)), jnp.float32)
    assert_close(ops.rank_update(m, u, v), ref.rank_update(m, u, v))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rank_update_dtypes(dtype, rng):
    m = jnp.asarray(rng.normal(size=(64, 64)), dtype)
    u = jnp.asarray(rng.normal(size=(64, 2)), dtype)
    v = jnp.asarray(rng.normal(size=(64, 2)), dtype)
    got = ops.rank_update(m, u, v)
    want = ref.rank_update(m, u, v)
    assert_close(got.astype(jnp.float32), want.astype(jnp.float32),
                 rtol=2e-2, atol=2e-2)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([32, 64, 96]), p=st.sampled_from([32, 64, 128]),
       k=st.integers(min_value=1, max_value=8),
       seed=st.integers(0, 1000))
def test_rank_update_property(n, p, k, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, k)), jnp.float32)
    assert_close(ops.rank_update(m, u, v), ref.rank_update(m, u, v))


@pytest.mark.parametrize("n,m,k", [
    (64, 64, 1), (128, 256, 4), (256, 128, 2), (96, 96, 8),
])
def test_dual_matmul_shapes(n, m, k, rng):
    a = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    p1, q1 = ops.dual_matmul(a, u, v)
    p2, q2 = ref.dual_matmul(a, u, v)
    assert_close(p1, p2, rtol=1e-3)
    assert_close(q1, q2, rtol=1e-3)


def test_dual_matmul_explicit_blocks(rng):
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(128, 2)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(128, 2)), jnp.float32)
    for bn in (32, 64, 128):
        p1, q1 = dual_matmul_pallas(a, u, v, bn=bn, interpret=True)
        p2, q2 = ref.dual_matmul(a, u, v)
        assert_close(p1, p2, rtol=1e-3)
        assert_close(q1, q2, rtol=1e-3)


def test_sherman_morrison_fused(rng):
    base = rng.normal(size=(96, 96))
    w = jnp.asarray(np.linalg.inv(base.T @ base + 5 * np.eye(96)),
                    jnp.float32)
    u = jnp.asarray(rng.normal(size=(96, 1)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(96, 1)), jnp.float32)
    l1, r1 = ops.sherman_morrison_delta(w, u, v)
    l2, r2 = ref.sherman_morrison_delta(w, u, v)
    assert_close(l1, l2, rtol=1e-3)
    assert_close(r1, r2, rtol=1e-3)
    # applying the delta gives the true new inverse
    from repro.core import sherman_morrison
    assert_close(w + l1 @ r1.T, sherman_morrison(w, u, v), rtol=1e-3)


@pytest.mark.parametrize("h,hkv,d,s,extra", [
    (8, 2, 64, 512, 0), (4, 4, 32, 256, 100), (16, 1, 64, 1024, 5),
    (8, 8, 128, 256, 0),
])
def test_flash_decode_shapes(h, hkv, d, s, extra, rng):
    q = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, hkv, d)), jnp.float32)
    ln = jnp.asarray(s - extra, jnp.int32)
    assert_close(ops.flash_decode(q, k, v, ln),
                 ref.flash_decode(q, k, v, ln), rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([128, 256, 384]),
       h=st.sampled_from([4, 8]),
       length_frac=st.floats(min_value=0.1, max_value=1.0),
       seed=st.integers(0, 500))
def test_flash_decode_property(s, h, length_frac, seed):
    rng = np.random.default_rng(seed)
    d = 32
    q = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
    ln = jnp.asarray(max(1, int(s * length_frac)), jnp.int32)
    got = ops.flash_decode(q, k, v, ln)
    want = ref.flash_decode(q, k, v, ln)
    assert_close(got, want, rtol=2e-3, atol=2e-3)


def test_trigger_with_pallas_backend(rng):
    """The codegen hook: triggers applied through the Pallas rank-update
    kernel give the same views as the XLA path."""
    from repro.apps import MatrixPowers
    ax = MatrixPowers(n=64, k=4, model="exp", apply_backend="pallas")
    bx = MatrixPowers(n=64, k=4, model="exp", apply_backend="xla")
    inputs = MatrixPowers.synthesize(64, seed=9)
    ax.initialize(inputs)
    bx.initialize(inputs)
    u, v = ax.row_update(0, rng.normal(size=64) * 0.1)
    assert_close(ax.update(u, v), bx.update(u, v), rtol=1e-4)


@pytest.mark.parametrize("s,hd,causal,bq,bk", [
    (256, 64, True, 128, 128), (512, 32, True, 256, 128),
    (256, 64, False, 64, 256), (384, 128, True, 128, 128),
])
def test_flash_attention_shapes(s, hd, causal, bq, bk, rng):
    from repro.kernels.flash_attention import flash_attention_pallas
    q = jnp.asarray(rng.normal(size=(s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, hd)), jnp.float32)
    got = flash_attention_pallas(q, k, v, bq=bq, bk=bk, causal=causal,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    assert_close(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_multihead_matches_blockwise(rng):
    """The Pallas kernel agrees with the model substrate's XLA blockwise
    attention (the thing it replaces on TPU)."""
    from repro.kernels import ops as kops
    from repro.models.attention import blockwise_attention
    b, s, h, hd = 2, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    got = kops.flash_attention(q, k, v, causal=True)
    want = blockwise_attention(q, k, v, causal=True)
    assert_close(got, want, rtol=2e-3, atol=2e-3)
