"""Batched trigger pipeline: equivalence, kernels, queue, stats, cost.

The contract under test (ISSUE 1): for any update stream,

    apply_updates([u_1..u_T])  ==  T × apply_update  ==  reevaluate

within fp tolerance, including the QR/SVD re-compression path and
ragged (non-power-of-two) batch sizes; plus the batched rank-update
kernel against its pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.ols import build_ols_program
from repro.core.compiler import batch_bucket, compile_batched_trigger
from repro.core.factored import (pad_factors_to_rank, recompress_factors,
                                 stack_update_arrays)
from repro.core.iterative import matrix_powers
from repro.core.runtime import IncrementalEngine, ReevalEngine, max_abs_diff
from repro.data.updates import UpdateStream
from repro.kernels import ops, ref

from conftest import assert_close


def _updates(n, m, count, seed=3, rank=1, zipf=None):
    it = iter(UpdateStream(n=n, m=m, rank=rank, scale=0.02, seed=seed,
                           zipf=zipf))
    return [next(it) for _ in range(count)]


def _ols_inputs(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return {"X": jnp.asarray(rng.normal(size=(m, n)), jnp.float32),
            "Y": jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)}


def _powers_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    a = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n))
    return {"A": jnp.asarray(a, jnp.float32)}


PROGRAMS = {
    "ols": (lambda: build_ols_program(96, 48, 1), lambda: _ols_inputs(96, 48),
            "X", 96, 48),
    "powers": (lambda: matrix_powers(k=8, n=48, model="exp"),
               lambda: _powers_inputs(48), "A", 48, 48),
}


# -- property: batched == sequential == reevaluation -------------------------


@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
@pytest.mark.parametrize("t_batch", [1, 3, 8, 16])  # 3: ragged, pads to 4
def test_batched_equals_sequential_and_reeval(prog_name, t_batch):
    build, inputs_fn, name, n, m = PROGRAMS[prog_name]
    ups = _updates(n, m, t_batch, seed=11 + t_batch)

    seq = IncrementalEngine(build())
    seq.initialize(inputs_fn())
    for u, v in ups:
        seq.apply_update(name, jnp.asarray(u), jnp.asarray(v))

    bat = IncrementalEngine(build())
    bat.initialize(inputs_fn())
    bat.apply_updates(name, ups, block=True)

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))

    assert max_abs_diff(seq.views, bat.views) < 1e-3
    outs = tuple(bat.program.output_names())
    assert max_abs_diff(bat.views, ree.views, outs) < 1e-3
    assert bat.stats.updates_applied == t_batch
    assert bat.stats.triggers_fired == 1


@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
def test_recompression_path_equivalence(prog_name):
    """Zipf-skewed streams exceed max_batch_rank → QR/SVD compaction fires
    and the result still matches plain re-evaluation."""
    build, inputs_fn, name, n, m = PROGRAMS[prog_name]
    ups = _updates(n, m, 16, seed=5, zipf=3.0)

    bat = IncrementalEngine(build(), max_batch_rank=6)
    bat.initialize(inputs_fn())
    bat.apply_updates(name, ups, block=True)
    assert bat.stats.recompressions == 1

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    outs = tuple(bat.program.output_names())
    assert max_abs_diff(bat.views, ree.views, outs) < 1e-3


def test_rank_k_updates_stack():
    """Batches of rank-2 updates stack to rank 2T and stay exact."""
    build, inputs_fn, name, n, m = PROGRAMS["ols"]
    ups = _updates(n, m, 5, seed=9, rank=2)  # stacked rank 10 → bucket 16
    bat = IncrementalEngine(build())
    bat.initialize(inputs_fn())
    bat.apply_updates(name, ups, block=True)
    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    assert max_abs_diff(bat.views, ree.views, ("beta",)) < 1e-3


def test_batched_pipeline_pallas_backend():
    """The batched engine with apply_backend='pallas' routes every view
    apply through the one-pass rank_update_batched kernel (interpret mode
    on CPU) and stays exact."""
    build, inputs_fn, name, n, m = PROGRAMS["powers"]
    bat = IncrementalEngine(build(), apply_backend="pallas")
    bat.initialize(inputs_fn())
    ups = _updates(n, m, 8, seed=17)
    bat.apply_updates(name, ups, block=True)

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    outs = tuple(bat.program.output_names())
    assert max_abs_diff(bat.views, ree.views, outs) < 1e-3


# -- factored-stack helpers ---------------------------------------------------


def test_stack_pad_recompress_roundtrip(rng):
    ups = [(rng.normal(size=(32, 2)).astype(np.float32),
            rng.normal(size=(24, 2)).astype(np.float32)) for _ in range(4)]
    P, Q = stack_update_arrays(ups)
    assert P.shape == (32, 8) and Q.shape == (24, 8)
    dense = sum(u @ v.T for u, v in ups)
    assert_close(P @ Q.T, dense)
    P2, Q2 = pad_factors_to_rank(P, Q, batch_bucket(11))
    assert P2.shape[1] == Q2.shape[1] == 16
    assert_close(P2 @ Q2.T, dense)
    # lossless re-compression: numerical rank of 8 random outer products is 8
    P3, Q3 = recompress_factors(P, Q)
    assert P3.shape[1] <= 8
    assert_close(P3 @ Q3.T, dense, rtol=1e-3, atol=1e-3)


def test_recompress_caps_rank(rng):
    # 8 copies of the same rank-1 update: numerical rank is 1
    u = rng.normal(size=(32, 1)).astype(np.float32)
    v = rng.normal(size=(24, 1)).astype(np.float32)
    P, Q = stack_update_arrays([(u, v)] * 8)
    P2, Q2 = recompress_factors(P, Q, tol=1e-4)
    assert P2.shape[1] == 1
    assert_close(P2 @ Q2.T, 8 * (u @ v.T), rtol=1e-3, atol=1e-3)


def test_batch_bucket():
    assert [batch_bucket(k) for k in (1, 2, 3, 4, 5, 8, 9, 64)] == \
        [1, 2, 4, 4, 8, 8, 16, 64]
    with pytest.raises(ValueError):
        batch_bucket(0)


def test_compile_batched_trigger_rank():
    build, _, name, _, _ = PROGRAMS["ols"]
    eng = IncrementalEngine(build())
    trig = compile_batched_trigger(eng.compiled, name, 8)
    assert trig.rank == 8
    assert trig.input_name == name


# -- batched rank-update kernel ----------------------------------------------


@pytest.mark.parametrize("n,p,k,t", [
    (64, 64, 1, 1), (128, 64, 2, 4), (64, 128, 4, 3),
    (96, 160, 3, 5), (8, 8, 1, 2), (64, 32, 2, 16),
])
def test_rank_update_batched_kernel(n, p, k, t, rng):
    m = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(t, n, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(t, p, k)), jnp.float32)
    assert_close(ops.rank_update_batched(m, u, v),
                 ref.rank_update_batched(m, u, v))


def test_rank_update_batched_2d_degenerate(rng):
    m = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    assert_close(ops.rank_update_batched(m, u, v), ref.rank_update(m, u, v))


def test_rank_update_batched_ragged_fallback(rng):
    # 17 is prime → no usable block, wrapper must fall back to the oracle
    m = jnp.asarray(rng.normal(size=(17, 23)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(2, 17, 1)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 23, 1)), jnp.float32)
    assert_close(ops.rank_update_batched(m, u, v),
                 ref.rank_update_batched(m, u, v))


def test_pick_block_properties():
    from repro.kernels.ops import _pick_block
    for n in (1, 8, 63, 64, 96, 100, 160, 256, 512, 777, 1000, 1024):
        for cap in (8, 100, 512):
            b = _pick_block(n, cap)
            assert n % b == 0 and 1 <= b <= max(cap, 1)


# -- update queue -------------------------------------------------------------


def test_queue_flushes_on_size():
    build, inputs_fn, name, n, m = PROGRAMS["ols"]
    eng = IncrementalEngine(build(), flush_size=4, flush_age=1e9)
    eng.initialize(inputs_fn())
    ups = _updates(n, m, 4, seed=21)
    for i, (u, v) in enumerate(ups):
        flushed = eng.enqueue_update(name, u, v)
        assert (flushed is not None) == (i == 3)
    assert eng.pending_rank(name) == 0
    assert eng.stats.batches_applied == 1
    assert eng.stats.updates_applied == 4

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    assert max_abs_diff(eng.views, ree.views, ("beta",)) < 1e-3


def test_queue_flushes_on_staleness():
    build, inputs_fn, name, n, m = PROGRAMS["ols"]
    eng = IncrementalEngine(build(), flush_size=100, flush_age=0.0)
    eng.initialize(inputs_fn())
    (u, v), = _updates(n, m, 1, seed=22)
    assert eng.enqueue_update(name, u, v) is not None  # age 0 → immediate
    assert eng.pending_rank(name) == 0


def test_explicit_flush_all_inputs():
    build, inputs_fn, name, n, m = PROGRAMS["ols"]
    eng = IncrementalEngine(build(), flush_size=100, flush_age=1e9)
    eng.initialize(inputs_fn())
    for u, v in _updates(n, m, 3, seed=23):
        assert eng.enqueue_update(name, u, v) is None
    assert eng.pending_rank(name) == 3
    eng.flush(block=True)
    assert eng.pending_rank(name) == 0
    assert eng.stats.updates_applied == 3


# -- stats accounting ---------------------------------------------------------


def test_stats_timed_vs_untimed():
    """trigger_seconds must pair with updates_timed, not updates_applied:
    async firings are counted but never timed."""
    build, inputs_fn, name, n, m = PROGRAMS["ols"]
    eng = IncrementalEngine(build())
    eng.initialize(inputs_fn())
    ups = _updates(n, m, 3, seed=31)
    eng.apply_update(name, *map(jnp.asarray, ups[0]))            # async
    eng.apply_update(name, *map(jnp.asarray, ups[1]), block=True)  # timed
    eng.apply_updates(name, [ups[2]], block=True)                  # timed
    assert eng.stats.updates_applied == 3
    assert eng.stats.updates_timed == 2
    assert eng.stats.triggers_fired == 3
    assert eng.stats.trigger_seconds > 0.0
    assert eng.stats.per_update_seconds() > 0.0


# -- serving-path contract ----------------------------------------------------


def test_logit_view_batched_contract(rng):
    """Adapter hot-swap deltas coalesce into one batched sweep of the
    corpus logits, matching the dense recompute."""
    from repro.serve.incremental_views import IncrementalLogitView
    H = rng.normal(size=(40, 16)).astype(np.float32)
    W = rng.normal(size=(10, 16)).astype(np.float32)
    view = IncrementalLogitView(H, W, flush_size=3, flush_age=1e9)
    ups = [(0.05 * rng.normal(size=(10, 1)).astype(np.float32),
            0.05 * rng.normal(size=(16, 1)).astype(np.float32))
           for _ in range(3)]
    assert not view.submit_head_update(*ups[0])
    assert not view.submit_head_update(*ups[1])
    assert view.pending_updates == 2
    assert view.submit_head_update(*ups[2])  # third delta trips flush_size
    assert view.pending_updates == 0
    W_new = W + sum(u @ v.T for u, v in ups)
    assert_close(view.logits, H @ W_new.T, rtol=1e-3, atol=1e-3)
    # batched entrypoint, no queue
    view2 = IncrementalLogitView(H, W)
    view2.update_head_batch(ups)
    assert_close(view2.logits, H @ W_new.T, rtol=1e-3, atol=1e-3)


# -- batched cost model -------------------------------------------------------


def test_batched_cost_model():
    from repro.core.cost import (apply_update_cost, batch_crossover_rank,
                                 batched_apply_cost, batched_strategy,
                                 recompress_cost)
    shape = (256, 256)
    seq = apply_update_cost(shape, 1)
    bat = batched_apply_cost(shape, 1, 16)
    assert bat.flops == pytest.approx(16 * seq.flops)
    # the batched pass reads/writes M once, not 16 times
    assert bat.bytes_rw < 16 * seq.bytes_rw
    assert recompress_cost(256, 256, 16).flops > 0

    reeval = 2.0 * 256 ** 3
    assert batched_strategy(shape, 4, 4, reeval) == "stacked"
    # stacked rank beyond the crossover with no compressibility → reeval
    assert batched_strategy(shape, 4096, 4096, reeval) == "reeval"
    assert batch_crossover_rank(shape, reeval) == 256
    # big views, wide batch, tiny numerical rank → compaction wins:
    # QR/SVD is view-size independent while the rank-K sweep is not
    big = (4096, 4096)
    assert batched_strategy(big, 512, 2, 2.0 * 4096 ** 3) == "recompress"
