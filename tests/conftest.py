"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""

import numpy as np
import pytest

# The container has no `hypothesis`; install the vendored deterministic
# shim so the property suites (test_kernels, test_property_delta) run as
# seeded parametrization instead of skipping.  A real install wins.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._vendor import hypothesis_shim
    hypothesis_shim.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_close(a, b, rtol=2e-4, atol=2e-4, msg=""):
    import jax.numpy as jnp
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    np.testing.assert_allclose(a, b, rtol=rtol, atol=atol, err_msg=msg)
