"""repro.plan: cost-based adaptive execution planning (ISSUE 5).

The contract under test: for any update stream and ANY maintenance plan,

    planned engine == unplanned incremental engine == full re-evaluation

within fp tolerance — a plan changes *how* views are refreshed
(incremental sweep, in-firing re-evaluation, hybrid switchover, lazy
skip + recompute-on-read), never the values they converge to.  Plus the
planner's §7 decision boundary, the per-view reeval fallback for
planless cost-policy engines, the persistent trigger cache (no re-jit
across engine instances for an identical plan key), online re-planning,
and the serving hot-swap contract.
"""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.ols import build_ols_program
from repro.core.cost import batch_crossover_rank
from repro.core.iterative import matrix_powers
from repro.core.runtime import IncrementalEngine, ReevalEngine, max_abs_diff
from repro.data.updates import UpdateStream
from repro.plan import (AdaptivePlanner, MaintenancePlan, TriggerCache,
                        ViewPlan, WorkloadDescriptor, calibrate_cost_scale,
                        plan_for_engine, plan_program, program_fingerprint,
                        static_plan)

from conftest import assert_close


def _updates(n, m, count, seed=3, rank=1):
    it = iter(UpdateStream(n=n, m=m, rank=rank, scale=0.02, seed=seed))
    return [next(it) for _ in range(count)]


def _ols_inputs(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return {"X": jnp.asarray(rng.normal(size=(m, n)), jnp.float32),
            "Y": jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)}


def _powers_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    a = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n))
    return {"A": jnp.asarray(a, jnp.float32)}


PROGRAMS = {
    "ols": (lambda: build_ols_program(96, 48, 1), lambda: _ols_inputs(96, 48),
            "X", 96, 48),
    "powers": (lambda: matrix_powers(k=8, n=48, model="exp"),
               lambda: _powers_inputs(48), "A", 48, 48),
}

# workloads that force each strategy regime (hybrid via a tiny forced
# threshold below, so the switchover actually fires at test sizes)
WORKLOADS = {
    "incremental": WorkloadDescriptor(batch_size=2),
    "reeval": WorkloadDescriptor(batch_size=100000),
}


def _forced_hybrid_plan(build, threshold=5):
    eng = IncrementalEngine(build())
    base = plan_for_engine(eng, WorkloadDescriptor())
    views = {n: replace(v, strategy="hybrid", threshold_rank=threshold)
             for n, v in base.views.items()}
    return MaintenancePlan(fingerprint=base.fingerprint,
                           workload=base.workload, views=views)


# -- property: planned == unplanned == reeval ---------------------------------


@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
@pytest.mark.parametrize("plan_kind", ["incremental", "reeval", "hybrid"])
@pytest.mark.parametrize("t_batch", [3, 8])  # 3: ragged, pads to bucket 4
def test_planned_equals_unplanned_and_reeval(prog_name, plan_kind, t_batch):
    build, inputs_fn, name, n, m = PROGRAMS[prog_name]
    ups = _updates(n, m, t_batch, seed=41 + t_batch)
    plan = (_forced_hybrid_plan(build) if plan_kind == "hybrid"
            else WORKLOADS[plan_kind])

    planned = IncrementalEngine(build(), plan=plan,
                                trigger_cache=TriggerCache())
    planned.initialize(inputs_fn())
    planned.apply_updates(name, ups, block=True)
    planned.refresh()

    plain = IncrementalEngine(build())
    plain.initialize(inputs_fn())
    plain.apply_updates(name, ups, block=True)

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))

    assert max_abs_diff(planned.views, plain.views) < 1e-3
    outs = tuple(planned.program.output_names())
    assert max_abs_diff(planned.views, ree.views, outs) < 1e-3
    assert planned.stats.updates_applied == t_batch
    assert planned.stats.triggers_fired == 1
    if plan_kind == "reeval":
        assert planned.stats.plan_reevals > 0


@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
def test_planned_per_update_stream_equivalence(prog_name):
    """Single-update firings through a forced-hybrid plan: the
    switchover happens mid-stream and every view stays exact."""
    build, inputs_fn, name, n, m = PROGRAMS[prog_name]
    ups = _updates(n, m, 9, seed=53)
    eng = IncrementalEngine(build(), plan=_forced_hybrid_plan(build, 4),
                            trigger_cache=TriggerCache())
    eng.initialize(inputs_fn())
    for u, v in ups:
        eng.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    # accumulated rank crossed the threshold at least twice
    assert eng.stats.plan_reevals > 0

    ree = ReevalEngine(build())
    ree.initialize(inputs_fn())
    for u, v in ups:
        ree.apply_update(name, jnp.asarray(u), jnp.asarray(v))
    outs = tuple(eng.program.output_names())
    assert max_abs_diff(eng.views, ree.views, outs) < 1e-3


def test_planned_mesh_matches_single_device():
    """The same plan on a 1-device mesh routes through the distributed
    planned trigger and stays exact vs the single-device planned path."""
    mesh = jax.make_mesh((1,), ("rows",))
    build, inputs_fn, name, n, m = PROGRAMS["powers"]
    plan_wl = WorkloadDescriptor(batch_size=100000)  # force in-firing reeval
    dist = IncrementalEngine(build(), mesh=mesh, plan=plan_wl,
                             trigger_cache=TriggerCache())
    single = IncrementalEngine(build(), plan=plan_wl,
                               trigger_cache=TriggerCache())
    dist.initialize(inputs_fn())
    single.initialize(inputs_fn())
    ups = _updates(n, m, 6, seed=59)
    dist.apply_updates(name, ups, block=True)
    single.apply_updates(name, ups, block=True)
    assert dist.stats.plan_reevals > 0
    assert max_abs_diff(dist.views, single.views) < 1e-4


# -- planner decisions --------------------------------------------------------


def test_planner_picks_reeval_past_crossover_and_incremental_below():
    eng = IncrementalEngine(build_ols_program(96, 48, 1))
    below = plan_for_engine(eng, WorkloadDescriptor(batch_size=2))
    assert all(vp.strategy == "incremental" for vp in below.views.values())

    above = plan_for_engine(eng, WorkloadDescriptor(batch_size=10 ** 6))
    assert all(vp.strategy == "reeval" for vp in above.views.values())

    # boundary check against the cost model, view by view
    for name, vp in below.views.items():
        st = eng.program.statement_for(name)
        from repro.core.cost import expr_cost, shape_of
        shape = shape_of(st.target, eng.binding)
        kstar = batch_crossover_rank(shape,
                                     expr_cost(st.expr, eng.binding).flops)
        assert vp.crossover_rank == kstar
        assert below.workload.expected_rank() < kstar
        assert above.workload.expected_rank() >= kstar


def test_planner_straddling_distribution_goes_hybrid():
    eng = IncrementalEngine(build_ols_program(96, 48, 1))
    kstars = sorted(vp.crossover_rank for vp in
                    plan_for_engine(eng, WorkloadDescriptor()).views.values())
    wl = WorkloadDescriptor(batch_size=kstars[0],
                            rank_lo=1, rank_hi=kstars[-1] + 1)
    plan = plan_for_engine(eng, wl)
    assert any(vp.strategy == "hybrid" for vp in plan.views.values())
    for vp in plan.views.values():
        if vp.strategy == "hybrid":
            assert vp.threshold_rank == vp.crossover_rank


def test_cost_scale_lowers_effective_crossover():
    """cost_scale > 1 (sweep FLOPs measured slower than reeval FLOPs)
    moves every strategy boundary down by that factor; the raw §7
    crossover stays in the plan as a diagnostic."""
    eng = IncrementalEngine(build_ols_program(96, 48, 1))
    base = plan_for_engine(eng, WorkloadDescriptor(batch_size=8))
    assert all(vp.strategy == "incremental" for vp in base.views.values())

    kstars = [vp.crossover_rank for vp in base.views.values()]
    scaled = plan_for_engine(
        eng, WorkloadDescriptor(batch_size=8, cost_scale=max(kstars)))
    # effective crossover is now ~1 for every view: all past it
    assert all(vp.strategy == "reeval" for vp in scaled.views.values())
    assert [vp.crossover_rank for vp in scaled.views.values()] == kstars

    # hybrid thresholds scale too
    hyb = plan_for_engine(
        eng, WorkloadDescriptor(batch_size=1, rank_lo=1, rank_hi=10 ** 6,
                                cost_scale=4.0))
    for vp in hyb.views.values():
        if vp.strategy == "hybrid":
            assert vp.threshold_rank == max(1, vp.crossover_rank // 4)


def test_static_plan_forces_strategy_and_stays_exact():
    build = lambda: build_ols_program(96, 48, 1)
    eng = IncrementalEngine(build(), trigger_cache=TriggerCache())
    eng.set_plan(static_plan(eng, "reeval"))
    eng.initialize(_ols_inputs(96, 48))
    ups = _updates(96, 48, 3, seed=77)
    eng.apply_updates("X", ups, block=True)
    assert eng.stats.plan_reevals > 0

    ree = ReevalEngine(build())
    ree.initialize(_ols_inputs(96, 48))
    for u, v in ups:
        ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    assert max_abs_diff(eng.views, ree.views, ("beta",)) < 1e-3


def test_calibrate_cost_scale_smoke():
    """The probe returns a positive finite scale and leaves no state
    behind that would break planning with it."""
    cache = TriggerCache()
    scale = calibrate_cost_scale(
        lambda: IncrementalEngine(build_ols_program(64, 32, 1),
                                  trigger_cache=cache),
        _ols_inputs(64, 32), "X", probe_rank=4, samples=2,
        trigger_cache=cache)
    assert 0 < scale < float("inf")
    eng = IncrementalEngine(build_ols_program(64, 32, 1))
    plan = plan_for_engine(eng, WorkloadDescriptor(batch_size=2,
                                                   cost_scale=scale))
    assert set(plan.views) == {"Z", "W", "beta"}


def test_plan_json_roundtrip():
    eng = IncrementalEngine(build_ols_program(96, 48, 1))
    plan = plan_for_engine(eng, WorkloadDescriptor(batch_size=4,
                                                   reads_per_firing=0.001))
    back = MaintenancePlan.from_json(plan.to_json())
    assert back.views == plan.views
    assert back.fingerprint == plan.fingerprint


def test_plan_json_roundtrip_with_mesh_key():
    """Distributed plans carry a nested-tuple mesh key; the JSON round
    trip must restore it exactly (tuples, not lists or mangled str)."""
    mesh = jax.make_mesh((1,), ("rows",))
    eng = IncrementalEngine(build_ols_program(96, 48, 1), mesh=mesh)
    plan = plan_for_engine(eng, WorkloadDescriptor(batch_size=4))
    assert plan.mesh_key is not None
    back = MaintenancePlan.from_json(plan.to_json())
    assert back.mesh_key == plan.mesh_key
    assert back.workload == plan.workload
    assert back.views == plan.views


def test_plan_fingerprint_mismatch_rejected():
    eng = IncrementalEngine(build_ols_program(96, 48, 1))
    other = IncrementalEngine(build_ols_program(64, 32, 1))
    plan = plan_for_engine(other, WorkloadDescriptor())
    with pytest.raises(ValueError):
        eng.set_plan(plan)


# -- per-view reeval fallback without a plan (cost flush policy) --------------


def test_cost_policy_firing_reevaluates_losing_view():
    """ROADMAP item: the 'cost' policy used to flush at the crossover but
    still fire the stacked trigger; the flushed firing must now
    re-evaluate exactly the views past their crossover."""
    eng = IncrementalEngine(build_ols_program(96, 48, 1),
                            flush_policy="cost", flush_age=1e9)
    eng.initialize(_ols_inputs(96, 48))
    k_star = eng.cost_flush_rank("X")
    ups = _updates(96, 48, k_star, seed=61)
    for u, v in ups:
        eng.enqueue_update("X", u, v)
    assert eng.stats.batches_applied == 1
    assert eng.stats.plan_reevals > 0  # some view fell back to reeval

    ree = ReevalEngine(build_ols_program(96, 48, 1))
    ree.initialize(_ols_inputs(96, 48))
    for u, v in ups:
        ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    assert max_abs_diff(eng.views, ree.views, ("beta",)) < 1e-3


# -- lazy materialization -----------------------------------------------------


def test_lazy_intermediate_skipped_then_refreshed():
    """With rare reads the planner unmaterializes Z (no trigger reads
    it); firings skip its sweep, reads recompute it exactly."""
    eng0 = IncrementalEngine(build_ols_program(96, 48, 1))
    plan = plan_for_engine(eng0, WorkloadDescriptor(batch_size=4,
                                                    reads_per_firing=1e-4))
    lazies = plan.lazy_views()
    assert "Z" in lazies          # intermediate nobody reads
    assert "beta" not in lazies   # outputs always materialize

    eng = IncrementalEngine(build_ols_program(96, 48, 1), plan=plan,
                            trigger_cache=TriggerCache())
    eng.initialize(_ols_inputs(96, 48))
    ups = _updates(96, 48, 4, seed=67)
    eng.apply_updates("X", ups, block=True)
    assert eng.stats.lazy_skips > 0
    assert "Z" in eng._stale

    ree = ReevalEngine(build_ols_program(96, 48, 1))
    ree.initialize(_ols_inputs(96, 48))
    for u, v in ups:
        ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    # output() refreshes stale views transparently
    assert_close(eng.output("beta"), ree.views["beta"], rtol=1e-3, atol=1e-3)
    assert not eng._stale
    assert max_abs_diff(eng.views, ree.views, ("Z", "W", "beta")) < 1e-3


def test_stale_lazy_view_recomputed_for_cross_trigger_reeval():
    """A lazy view left stale by one input's firing must be refreshed
    inside a LATER firing of a different input whose plan re-evaluates
    a consumer — the recompute closure may not read the stale value."""
    from repro.core import Program, dim, matmul

    n = 16
    prog = Program(name="xtrig")
    N = dim("n")
    A = prog.input("A", (N, N))
    B = prog.input("B", (N, N))
    L = prog.let("L", matmul(B, B))
    prog.let("R", matmul(A, L))
    prog.bind_dims(n=n)

    eng0 = IncrementalEngine(prog, {"A": 1, "B": 1})
    base = plan_for_engine(eng0, WorkloadDescriptor())
    # R hybrid w/ threshold 2: the B-firing keeps R incremental (so L's
    # sweep is skipped and L goes stale), the A-firing crosses the
    # accumulated-rank threshold and re-evaluates R — reading L
    plan = MaintenancePlan(
        fingerprint=base.fingerprint, workload=base.workload,
        views={"L": replace(base.views["L"], strategy="incremental",
                            materialize=False),
               "R": replace(base.views["R"], strategy="hybrid",
                            threshold_rank=2, materialize=True)})
    eng = IncrementalEngine(prog, {"A": 1, "B": 1}, plan=plan,
                            trigger_cache=TriggerCache())
    rng = np.random.default_rng(11)
    A0 = rng.normal(size=(n, n)).astype(np.float32)
    B0 = rng.normal(size=(n, n)).astype(np.float32)
    eng.initialize({"A": jnp.asarray(A0), "B": jnp.asarray(B0)})

    fac = lambda s: 0.1 * rng.normal(size=(n, 1)).astype(np.float32)
    u1, v1, u2, v2 = fac(1), fac(2), fac(3), fac(4)
    eng.apply_update("B", jnp.asarray(u1), jnp.asarray(v1))
    assert "L" in eng._stale            # lazy skip left L stale
    eng.apply_update("A", jnp.asarray(u2), jnp.asarray(v2))

    A1 = A0 + u2 @ v2.T
    B1 = B0 + u1 @ v1.T
    R_true = A1 @ (B1 @ B1)
    assert np.abs(np.asarray(eng.views["R"]) - R_true).max() < 1e-4
    eng.flush(block=True)               # exactness point clears L too
    assert not eng._stale
    assert np.abs(np.asarray(eng.views["L"]) - B1 @ B1).max() < 1e-4


# -- persistent trigger cache -------------------------------------------------


def test_trigger_cache_no_rejit_on_second_engine():
    """Two engines, identical program/sizes/plan: the second must reuse
    every compiled trigger — zero new cache entries, so no re-trace and
    no re-jit (jax's jit cache keys on function identity)."""
    cache = TriggerCache()
    wl = WorkloadDescriptor(batch_size=4)
    ups = _updates(96, 48, 8, seed=71)

    eng1 = IncrementalEngine(build_ols_program(96, 48, 1), plan=wl,
                             trigger_cache=cache)
    eng1.initialize(_ols_inputs(96, 48))
    eng1.apply_update("X", *map(jnp.asarray, ups[0]))
    eng1.apply_updates("X", ups, block=True)
    misses_after_first = cache.misses
    assert misses_after_first > 0

    eng2 = IncrementalEngine(build_ols_program(96, 48, 1), plan=wl,
                             trigger_cache=cache)
    eng2.initialize(_ols_inputs(96, 48, seed=1))
    eng2.apply_update("X", *map(jnp.asarray, ups[0]))
    eng2.apply_updates("X", ups, block=True)
    assert cache.misses == misses_after_first  # not a single rebuild
    assert cache.hits > 0
    # same function object ⇒ same jax jit cache entry
    assert eng2._trigger_fns["X"] is eng1._trigger_fns["X"]
    assert (eng2._batched_trigger_fn("X", 8)
            is eng1._batched_trigger_fn("X", 8))

    # different sizes → different fingerprint → no false sharing
    eng3 = IncrementalEngine(build_ols_program(64, 32, 1), plan=wl,
                             trigger_cache=cache)
    eng3.initialize(_ols_inputs(64, 32))
    eng3.apply_update("X", *map(jnp.asarray, _updates(64, 32, 1, seed=3)[0]))
    assert cache.misses > misses_after_first


def test_trigger_cache_spans_mesh_key():
    """Identical 1-device meshes share distributed planned triggers
    through the cache; the mesh key tells them apart from the
    single-device entries."""
    cache = TriggerCache()
    build, inputs_fn, name, n, m = PROGRAMS["powers"]
    wl = WorkloadDescriptor(batch_size=100000)
    ups = _updates(n, m, 2, seed=73)

    mesh1 = jax.make_mesh((1,), ("rows",))
    e1 = IncrementalEngine(build(), mesh=mesh1, plan=wl, trigger_cache=cache)
    e1.initialize(inputs_fn())
    e1.apply_updates(name, ups, block=True)
    misses = cache.misses

    mesh2 = jax.make_mesh((1,), ("rows",))
    e2 = IncrementalEngine(build(), mesh=mesh2, plan=wl, trigger_cache=cache)
    e2.initialize(inputs_fn())
    e2.apply_updates(name, ups, block=True)
    assert cache.misses == misses  # same mesh key → shared triggers
    assert max_abs_diff(e1.views, e2.views) < 1e-4


# -- adaptive re-planning -----------------------------------------------------


def test_adaptive_planner_replans_on_drift():
    planner = AdaptivePlanner(WorkloadDescriptor(batch_size=1),
                              replan_every=4)
    eng = IncrementalEngine(build_ols_program(96, 48, 1), plan=planner,
                            trigger_cache=TriggerCache())
    eng.initialize(_ols_inputs(96, 48))
    assert all(vp.strategy == "incremental"
               for vp in eng.plan.views.values())

    for i in range(4):  # matches the declared workload: no replan
        eng.apply_updates("X", _updates(96, 48, 1, seed=80 + i))
    assert eng.stats.replans == 0

    for i in range(8):  # drift: firings far past every crossover
        eng.apply_updates("X", _updates(96, 48, 160, seed=90 + i))
    assert eng.stats.replans >= 1
    assert any(vp.strategy != "incremental"
               for vp in eng.plan.views.values())

    # exactness is preserved across the hot-swap
    ree = ReevalEngine(build_ols_program(96, 48, 1))
    ree.initialize(_ols_inputs(96, 48))
    for i in range(4):
        for u, v in _updates(96, 48, 1, seed=80 + i):
            ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    for i in range(8):
        for u, v in _updates(96, 48, 160, seed=90 + i):
            ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    eng.refresh()
    assert max_abs_diff(eng.views, ree.views, ("beta",)) < 5e-3


def test_adaptive_planner_observes_per_update_path():
    """apply_update (non-batched) firings feed the observation loop too
    — a serving client driving single updates still gets re-planning."""
    planner = AdaptivePlanner(WorkloadDescriptor(batch_size=100000),
                              replan_every=4)
    eng = IncrementalEngine(build_ols_program(96, 48, 1), plan=planner,
                            trigger_cache=TriggerCache())
    eng.initialize(_ols_inputs(96, 48))
    assert all(vp.strategy == "reeval" for vp in eng.plan.views.values())
    for u, v in _updates(96, 48, 8, seed=83):  # drift: rank-1 stream
        eng.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    assert eng.stats.replans >= 1
    assert any(vp.strategy == "incremental"
               for vp in eng.plan.views.values())


def test_set_plan_syncs_adaptive_planner():
    """A hot-swapped external plan becomes the planner's baseline — the
    next drift check must not silently revert it."""
    planner = AdaptivePlanner(WorkloadDescriptor(batch_size=1))
    eng = IncrementalEngine(build_ols_program(96, 48, 1), plan=planner,
                            trigger_cache=TriggerCache())
    swapped = plan_for_engine(eng, WorkloadDescriptor(batch_size=100000))
    eng.set_plan(swapped)
    assert planner.plan is swapped
    assert planner.workload == swapped.workload


def test_adaptive_planner_binding_guard():
    planner = AdaptivePlanner(WorkloadDescriptor())
    IncrementalEngine(build_ols_program(96, 48, 1), plan=planner)
    with pytest.raises(ValueError):
        IncrementalEngine(build_ols_program(64, 32, 1), plan=planner)


# -- serving hot-swap contract ------------------------------------------------


def test_logit_view_replan_keeps_staleness_contract(rng):
    from repro.serve.incremental_views import IncrementalLogitView
    H = rng.normal(size=(40, 16)).astype(np.float32)
    W = rng.normal(size=(10, 16)).astype(np.float32)
    view = IncrementalLogitView(H, W, flush_size=3, flush_age=1e9)
    ups = [(0.05 * rng.normal(size=(10, 1)).astype(np.float32),
            0.05 * rng.normal(size=(16, 1)).astype(np.float32))
           for _ in range(3)]
    assert not view.submit_head_update(*ups[0])
    assert not view.submit_head_update(*ups[1])
    assert view.pending_updates == 2

    # re-plan mid-stream: pending deltas survive the swap
    plan = view.replan(WorkloadDescriptor(batch_size=2))
    assert view.engine.plan is plan
    assert view.pending_updates == 2

    assert view.submit_head_update(*ups[2])  # flush_size still trips
    assert view.pending_updates == 0
    W_new = W + sum(u @ v.T for u, v in ups)
    assert_close(view.logits, H @ W_new.T, rtol=1e-3, atol=1e-3)


def test_serve_engine_replan_views(rng):
    """ServeEngine.replan_views hot-swaps a plan into every attached
    logit view without touching their queues."""
    from repro.serve.engine import ServeEngine
    from repro.serve.incremental_views import IncrementalLogitView

    class _Stub(ServeEngine):  # avoid building an LM for a plan test
        def __init__(self):
            self._logit_views = {}

    eng = _Stub()
    H = rng.normal(size=(24, 8)).astype(np.float32)
    W = rng.normal(size=(6, 8)).astype(np.float32)
    eng._logit_views["lm_head"] = IncrementalLogitView(H, W, flush_size=8,
                                                       flush_age=1e9)
    eng._logit_views["lm_head"].submit_head_update(
        0.1 * rng.normal(size=(6, 1)).astype(np.float32),
        0.1 * rng.normal(size=(8, 1)).astype(np.float32))
    plans = eng.replan_views(WorkloadDescriptor(batch_size=4))
    assert set(plans) == {"lm_head"}
    assert eng._logit_views["lm_head"].pending_updates == 1
