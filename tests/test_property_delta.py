"""Hypothesis property tests for the LINVIEW invariants.

Invariants under random programs / shapes / update ranks:
  P1  exactness: trigger-maintained views == re-evaluated views
  P2  factored-rank bound: rank(ΔE) ≤ structural bound (2× per squaring)
  P3  delta of a static expression is zero
  P4  transpose duality: Δ(Eᵀ) == (ΔE)ᵀ numerically
  P5  Woodbury == sequential Sherman–Morrison
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis is not installed in this container")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (IncrementalEngine, LowRank, Program, ReevalEngine,
                        add, derive, DeltaEnv, dim, matmul, scale, transpose,
                        var)
from repro.core.iterative import matrix_powers

from conftest import assert_close


dims = st.integers(min_value=4, max_value=24)
ranks = st.integers(min_value=1, max_value=3)
seeds = st.integers(min_value=0, max_value=2 ** 16)


def _mats(seed, n, k):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(n, n)) / np.sqrt(n), dtype=jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, k)) * 0.2, dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, k)) * 0.2, dtype=jnp.float32)
    return A, u, v


@settings(max_examples=25, deadline=None)
@given(n=dims, k=ranks, seed=seeds,
       model=st.sampled_from(["linear", "exp", "skip"]),
       steps=st.integers(min_value=1, max_value=3))
def test_p1_exactness_matrix_powers(n, k, seed, model, steps):
    A, u, v = _mats(seed, n, k)
    prog = matrix_powers(k=8, n=n, model=model, s=4)
    inc = IncrementalEngine(prog, {"A": k})
    ree = ReevalEngine(prog)
    inc.initialize({"A": A})
    ree.initialize({"A": A})
    for _ in range(steps):
        inc.apply_update("A", u, v)
        ree.apply_update("A", u, v)
    out = prog.output_names()[0]
    ref = np.asarray(ree.views[out])
    scale_ = max(np.abs(ref).max(), 1.0)
    assert_close(np.asarray(inc.views[out]) / scale_, ref / scale_,
                 rtol=5e-3, atol=5e-3)


@settings(max_examples=30, deadline=None)
@given(k=ranks, depth=st.integers(min_value=1, max_value=4))
def test_p2_rank_growth_bound(k, depth):
    n = 16
    A = var("A", (n, n))
    env = DeltaEnv()
    env.deltas["A"] = LowRank.outer(var("u", (n, k)), var("v", (n, k)))
    e = A
    for _ in range(depth):
        e = matmul(e, e)
    d = derive(e, env)
    assert isinstance(d, LowRank)
    assert d.rank <= k * (2 ** depth)


@settings(max_examples=20, deadline=None)
@given(n=dims, k=ranks, seed=seeds)
def test_p4_transpose_duality(n, k, seed):
    A, u, v = _mats(seed, n, k)
    env = DeltaEnv()
    env.deltas["A"] = LowRank.outer(var("u", (n, k)), var("v", (n, k)))
    Av = var("A", (n, n))
    e = matmul(Av, transpose(Av))
    d1 = derive(e, env)
    d2 = derive(transpose(e), env)
    vals = {"A": A, "u": u, "v": v}
    from repro.core import evaluate

    def val(d):
        tot = 0.0
        for l, r in zip(d.left, d.right):
            tot = tot + evaluate(l, vals, {}) @ evaluate(r, vals, {}).T
        return tot

    assert_close(val(d1).T, val(d2), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=dims, k=st.integers(min_value=1, max_value=3), seed=seeds)
def test_p5_woodbury_equals_sequential_sm(n, k, seed):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(n, n))
    Z = jnp.asarray(base.T @ base + 4 * np.eye(n), dtype=jnp.float32)
    W = jnp.linalg.inv(Z)
    p = jnp.asarray(rng.normal(size=(n, k)) * 0.2, dtype=jnp.float32)
    q = jnp.asarray(rng.normal(size=(n, k)) * 0.2, dtype=jnp.float32)
    from repro.core import woodbury, sherman_morrison
    w1 = woodbury(W, p, q)
    w2 = W
    for i in range(k):
        w2 = sherman_morrison(w2, p[:, i], q[:, i])
    assert_close(w1, w2, rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(n=dims, seed=seeds)
def test_p3_static_zero(n, seed):
    env = DeltaEnv()
    env.deltas["A"] = LowRank.outer(var("u", (n, 1)), var("v", (n, 1)))
    B = var("B", (n, n))
    d = derive(add(matmul(B, B), scale(3.0, B)), env)
    assert isinstance(d, LowRank) and d.is_zero()
