"""Distributed execution tests.

These need >1 device, so each test body runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (smoke tests in this process
must keep seeing 1 device).
"""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_distributed_ivm_trigger_matches_single_device():
    """Paper §6: row-sharded trigger execution == single-device trigger."""
    _run("""
    from jax.sharding import Mesh
    from repro.core import IncrementalEngine
    from repro.core.iterative import matrix_powers
    from repro.dist.ivm_shard import build_distributed_trigger

    n, k = 64, 8
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(n, n)) / 8, jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, 1)) * .2, jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, 1)) * .2, jnp.float32)

    prog = matrix_powers(k=k, n=n, model="exp")
    eng = IncrementalEngine(prog, {"A": 1})
    eng.initialize({"A": A})
    views0 = {kk: vv for kk, vv in eng.views.items()}

    mesh = jax.make_mesh((8,), ("rows",))
    trig = eng.compiled.triggers["A"]
    fn = build_distributed_trigger(trig, eng.program, mesh)
    out = fn(views0, u, v)

    eng.apply_update("A", u, v)
    for name in ["A", "P2", "P4", "P8"]:
        got = np.asarray(out[name])
        want = np.asarray(eng.views[name])
        scale = max(np.abs(want).max(), 1.0)
        err = np.abs(got - want).max() / scale
        assert err < 1e-4, (name, err)
    print("dist IVM OK")
    """)


def test_distributed_reeval_matmul():
    _run("""
    from repro.dist.ivm_shard import distributed_reeval_matmul
    mesh = jax.make_mesh((8,), ("rows",))
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    fn = distributed_reeval_matmul(mesh)
    np.testing.assert_allclose(np.asarray(fn(A, B)), np.asarray(A @ B),
                               rtol=1e-4, atol=1e-4)
    print("dist reeval OK")
    """)


def test_compressed_psum_reduces_like_mean_of_lowrank():
    """The shard_map compressed all-reduce: psum of factors reconstructs
    the mean gradient (exactly, when per-shard grads are rank-1 and share
    the right subspace seed)."""
    _run("""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.train import grad_compression as gc

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(2)
    # same rank-1 gradient on every shard → compressed psum must equal it
    u = rng.normal(size=(64, 1)).astype(np.float32)
    v = rng.normal(size=(32, 1)).astype(np.float32)
    g_local = u @ v.T
    g_global = jnp.asarray(np.tile(g_local.reshape(1, 64, 32), (8, 1, 1))
                           ).reshape(8 * 64, 32)
    # treat leading dim as the sharded batch-of-grads: reshape inside
    grads = {"w": jnp.asarray(g_local)}   # per-shard identical
    state = gc.init_compression(grads, rank=2, min_dim=16)
    out = gc.compressed_psum(mesh, "data", grads, state)
    np.testing.assert_allclose(np.asarray(out["w"]), g_local,
                               rtol=1e-3, atol=1e-3)
    print("compressed psum OK")
    """)


def test_pjit_train_step_small_mesh():
    """A reduced arch train step lowers AND RUNS on a (4, 2) mesh with the
    production sharding rules (numerical, not just dry-run)."""
    _run("""
    from repro.configs import get_config
    from repro.dist.sharding import use_sharding, tree_shardings, named_sharding
    from repro.models import build_model
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with use_sharding(mesh):
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model))
        batch = {"tokens": jnp.ones((8, 64), jnp.int32)}
        state2, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), metrics
    print("pjit train OK", float(metrics["loss"]))
    """)


def test_moe_sharded_matches_local():
    """The shard_map MoE path (EP) equals the single-device path."""
    _run("""
    import dataclasses
    from repro.configs import get_config
    from repro.dist.sharding import use_sharding
    from repro.models import build_model

    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab)}
    logits_local, _ = model.forward(params, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))  # 8 experts / 4 = 2 per shard
    with use_sharding(mesh):
        logits_sharded, _ = jax.jit(model.forward)(params, batch)
    a = np.asarray(logits_local, np.float32)
    b = np.asarray(logits_sharded, np.float32)
    err = np.abs(a - b).max() / max(np.abs(a).max(), 1.0)
    assert err < 5e-3, err
    print("moe EP OK", err)
    """)


def test_elastic_remesh_checkpoint_reshard(tmp_path=None):
    """Elastic scaling end-to-end: train on a (4,2) mesh, checkpoint,
    'lose' half the data hosts, resume on a (2,2) sub-mesh with re-resolved
    shardings — the checkpoint is mesh-independent."""
    _run("""
    import tempfile
    from repro.configs import get_config
    from repro.dist.checkpoint import CheckpointManager
    from repro.dist.fault_tolerance import plan_mesh
    from repro.dist.sharding import use_sharding
    from repro.models import build_model
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    batch = {"tokens": jnp.ones((8, 64), jnp.int32)}
    ckdir = tempfile.mkdtemp()

    # phase 1: (4, 2) mesh
    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    with use_sharding(mesh1):
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model))
        state, m1 = step(state, batch)
    mgr = CheckpointManager(ckdir, async_save=False)
    mgr.save(1, state, blocking=True)

    # phase 2: 4 devices survive → plan a (2, 2) mesh, reshard on restore
    shape, names = plan_mesh(4, 2)
    assert shape == (2, 2)
    mesh2 = jax.make_mesh(shape, names)
    with use_sharding(mesh2):
        fresh = init_train_state(model, jax.random.PRNGKey(0))
        restored = mgr.restore(fresh, step=1)
        step2 = jax.jit(make_train_step(model))
        restored, m2 = step2(restored, batch)
    assert bool(jnp.isfinite(m2["loss"])), m2
    # the restored run continues from the same loss surface
    assert abs(float(m2["loss"]) - float(m1["loss"])) < 2.0
    print("elastic remesh OK", float(m1["loss"]), float(m2["loss"]))
    """)
