"""repro.fleet tests: leases/fencing, admission, exactly-once commit
under worker crashes + lease expiry, the N-tenants-bit-identical-to-N-
isolated-engines property, overload tiers, noisy-neighbor quarantine,
and the 500-firing fleet chaos acceptance run — plus the satellite
regressions (thread-safe TriggerCache, chain-aware planner pricing,
deterministic degrade clocks).

The chaos tests run under REPRO_CHAOS_SEEDS (comma-separated; default
"0" locally, a matrix in CI).
"""

import os
import threading

import numpy as np
import pytest

from repro.apps.ols import build_ols_program
from repro.core.compiler import compile_program
from repro.core.runtime import IncrementalEngine, max_abs_diff
from repro.fleet import (ADMITTED, QUEUE_FULL, SHED, THROTTLED, FleetConfig,
                         FleetScheduler, LeaseStore, OverloadPolicy,
                         TenantSpec, TokenBucket, WorkerCrashed)
from repro.guard import ChaosConfig, CircuitBreaker, DegradePolicy, \
    retry_with_backoff
from repro.plan import (TriggerCache, WorkloadDescriptor, firing_cost_flops,
                        plan_program, trigger_chain_costs)
from repro.serve.incremental_views import build_logit_view_program

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("REPRO_CHAOS_SEEDS", "0").split(",")]


class VClock:
    """Deterministic virtual time for lease/breaker/backoff tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt

    def sleep(self, dt: float) -> None:
        self.t += dt


def _ols_tenant(m=24, n=6, p=1, seed=0):
    rng = np.random.default_rng(seed)
    prog = build_ols_program(m, n, p)
    inputs = {"X": rng.standard_normal((m, n)).astype(np.float32),
              "Y": rng.standard_normal((m, p)).astype(np.float32)}
    return prog, inputs


def _logit_tenant(m=8, d=4, p=5, seed=0):
    rng = np.random.default_rng(seed)
    prog = build_logit_view_program(m, d, p)
    inputs = {"H": rng.standard_normal((m, d)).astype(np.float32),
              "W": (rng.standard_normal((p, d)) * 0.1).astype(np.float32)}
    return prog, inputs


def _rank1(rng, n, m, scale=0.1):
    return ((rng.standard_normal((n, 1)) * scale).astype(np.float32),
            (rng.standard_normal((m, 1)) * scale).astype(np.float32))


def _replay_reference(tenant, inputs, updates_by_lsn):
    """An isolated engine fed the tenant's committed firing groups in
    commit order — the fleet's committed store must match it
    bit-identically (same guard config, same grouping, same values)."""
    ref = IncrementalEngine(tenant.spec.program, tenant.spec.update_ranks,
                            guard=tenant.spec.guarded or None)
    ref.initialize(inputs)
    for input_name, lsns in tenant.commit_log:
        assert input_name != "<reeval>", "property test must not degrade"
        ref.apply_updates(input_name,
                          [updates_by_lsn[l] for l in lsns])
    return ref


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------

def test_lease_claim_renew_release():
    vc = VClock()
    store = LeaseStore(ttl=1.0, clock=vc)
    lease = store.claim("t1", "w1")
    assert lease is not None and lease.token == 1
    # live lease blocks everyone, including the holder (not reentrant)
    assert store.claim("t1", "w2") is None
    assert store.claim("t1", "w1") is None
    vc.advance(0.6)
    assert store.renew(lease)          # extended to t=1.6
    vc.advance(0.8)
    assert store.is_current(lease)     # t=1.4 < 1.6
    assert store.release(lease)
    assert not store.is_current(lease)
    lease2 = store.claim("t1", "w2")   # freed: next claim wins token 2
    assert lease2 is not None and lease2.token == 2
    assert store.stats()["reclaims"] == 0


def test_lease_expiry_reclaim_and_fencing():
    vc = VClock()
    store = LeaseStore(ttl=1.0, clock=vc)
    stale = store.claim("t1", "w1")
    vc.advance(1.5)                    # w1 dies; TTL runs out
    assert store.expired() and store.expired()[0] is stale
    fresh = store.claim("t1", "w2")    # reclaim
    assert fresh is not None and fresh.token == 2
    assert store.stats()["reclaims"] == 1
    # the zombie is fenced out of every path
    assert not store.is_current(stale)
    assert not store.renew(stale)
    assert not store.release(stale)
    assert store.stats()["fence_rejections"] == 2
    assert store.is_current(fresh)     # the reclaimer is unaffected


def test_lease_break_is_indistinguishable_from_expiry():
    vc = VClock()
    store = LeaseStore(ttl=10.0, clock=vc)
    lease = store.claim("t1", "w1")
    assert store.break_lease("t1")     # chaos lease_expiry_p path
    assert not store.is_current(lease)
    assert store.holder("t1") is None
    assert store.claim("t1", "w2") is not None
    assert store.stats()["broken"] == 1


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------

def test_token_bucket_refill():
    vc = VClock()
    b = TokenBucket(rate=2.0, burst=4, clock=vc)
    assert all(b.allow() for _ in range(4))   # full burst
    assert not b.allow()                      # empty
    vc.advance(1.0)                           # +2 tokens
    assert b.allow() and b.allow() and not b.allow()
    vc.advance(100.0)
    assert b.available() == 4                 # capped at burst


def test_admission_throttle_queue_full_and_shed():
    vc = VClock()
    fleet = FleetScheduler(FleetConfig(lease_ttl=1.0), clock=vc,
                           sleep=vc.sleep)
    prog, inputs = _logit_tenant()
    # sheddable=False so the full queue exposes QUEUE_FULL back-pressure
    # instead of tripping the shedding tier first (covered elsewhere)
    fleet.add_tenant(TenantSpec("t1", prog, {"W": 1}, quota_rate=1.0,
                                quota_burst=2, queue_capacity=3,
                                sheddable=False), inputs)
    rng = np.random.default_rng(0)
    ups = [_rank1(rng, 5, 4) for _ in range(4)]
    assert fleet.submit("t1", "W", *ups[0]) == ADMITTED
    assert fleet.submit("t1", "W", *ups[1]) == ADMITTED
    assert fleet.submit("t1", "W", *ups[2]) == THROTTLED   # bucket empty
    vc.advance(2.0)                                        # refill 2
    assert fleet.submit("t1", "W", *ups[2]) == ADMITTED
    assert fleet.submit("t1", "W", *ups[3]) == QUEUE_FULL  # log at cap 3
    t = fleet.registry.get("t1")
    assert t.stats.decisions == {ADMITTED: 3, THROTTLED: 1, QUEUE_FULL: 1}
    with pytest.raises(KeyError):
        fleet.submit("t1", "nope", *ups[0])


# ---------------------------------------------------------------------------
# the claim/commit protocol
# ---------------------------------------------------------------------------

def test_commit_is_bit_identical_to_isolated_engine():
    vc = VClock()
    fleet = FleetScheduler(FleetConfig(lease_ttl=1.0), clock=vc,
                           sleep=vc.sleep)
    prog, inputs = _ols_tenant()
    tenant = fleet.add_tenant(TenantSpec("acme", prog, {"X": 1}), inputs)
    rng = np.random.default_rng(1)
    by_lsn = {}
    for i in range(7):
        u, v = _rank1(rng, 24, 6)
        assert fleet.submit("acme", "X", u, v) == ADMITTED
        by_lsn[i + 1] = (u, v)
    fleet.run_until_idle(workers=2, on_stall=lambda: vc.advance(1.1))
    assert not tenant.dirty()
    assert tenant.stats.committed_updates == 7
    ref = _replay_reference(tenant, inputs, by_lsn)
    assert max_abs_diff(tenant.committed_views, ref.views) == 0.0


def test_worker_crash_replay_exactly_once():
    vc = VClock()
    # crash every claim until we disarm the monkey
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    chaos=ChaosConfig(seed=0, worker_crash_p=1.0)),
        clock=vc, sleep=vc.sleep)
    prog, inputs = _logit_tenant()
    tenant = fleet.add_tenant(TenantSpec("t1", prog, {"W": 1}), inputs)
    rng = np.random.default_rng(2)
    by_lsn = {}
    for i in range(5):
        u, v = _rank1(rng, 5, 4)
        fleet.submit("t1", "W", u, v)
        by_lsn[i + 1] = (u, v)
    committed_before = dict(tenant.committed_views)
    with pytest.raises(WorkerCrashed):
        fleet.run_claim("w1")
    # the dead claim left its lease and uncommitted engine state behind
    assert tenant.inflight is not None
    assert fleet.leases.holder("t1") is not None
    assert tenant.applied_lsn == 0
    # committed reads never saw any of it
    assert max_abs_diff(tenant.committed_views, committed_before) == 0.0
    # TTL not yet expired: nobody can reclaim
    assert fleet.run_claim("w2") == "idle"
    vc.advance(1.5)
    fleet.chaos = None                 # second incarnation is healthy
    assert fleet.run_claim("w2") == "committed"
    assert tenant.stats.replays == 1   # rolled the dead claim back
    assert fleet.leases.stats()["reclaims"] == 1
    assert tenant.stats.committed_updates == 5   # exactly once
    assert not tenant.dirty()
    ref = _replay_reference(tenant, inputs, by_lsn)
    assert max_abs_diff(tenant.committed_views, ref.views) == 0.0


def test_lease_expiry_fences_commit_and_rolls_back():
    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    chaos=ChaosConfig(seed=0, lease_expiry_p=1.0)),
        clock=vc, sleep=vc.sleep)
    prog, inputs = _logit_tenant()
    tenant = fleet.add_tenant(TenantSpec("t1", prog, {"W": 1}), inputs)
    rng = np.random.default_rng(3)
    u, v = _rank1(rng, 5, 4)
    fleet.submit("t1", "W", u, v)
    assert fleet.run_claim("w1") == "fenced"
    # fenced claims roll their own work back: nothing applied,
    # nothing committed, log intact for the next worker
    assert tenant.stats.fenced_aborts == 1
    assert tenant.applied_lsn == 0 and tenant.dirty()
    assert tenant.inflight is None
    fleet.chaos = None
    assert fleet.run_claim("w2") == "committed"
    assert tenant.stats.committed_updates == 1   # exactly once
    ref = _replay_reference(tenant, inputs, {1: (u, v)})
    assert max_abs_diff(tenant.committed_views, ref.views) == 0.0


def test_max_claim_rank_bounds_one_claim():
    vc = VClock()
    fleet = FleetScheduler(FleetConfig(lease_ttl=1.0), clock=vc,
                           sleep=vc.sleep)
    prog, inputs = _logit_tenant()
    tenant = fleet.add_tenant(
        TenantSpec("t1", prog, {"W": 1}, max_claim_rank=3), inputs)
    rng = np.random.default_rng(4)
    for _ in range(8):
        fleet.submit("t1", "W", *_rank1(rng, 5, 4))
    assert fleet.run_claim("w1") == "committed"
    assert tenant.applied_lsn == 3          # capped claim
    assert tenant.stats.committed_updates == 3
    fleet.run_until_idle(on_stall=lambda: vc.advance(1.1))
    assert tenant.applied_lsn == 8 and not tenant.dirty()


# ---------------------------------------------------------------------------
# the bit-identical N-tenant property + chaos acceptance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fleet_property_bit_identical_to_isolated_engines(seed):
    """N tenants under interleaved updates, worker crashes, and lease
    expiries produce committed stores bit-identical to N isolated
    single-tenant engines replaying each tenant's committed groups —
    which is simultaneously the exactly-once proof and the
    no-cross-tenant-contamination proof."""
    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    chaos=ChaosConfig(seed=seed, worker_crash_p=0.2,
                                      lease_expiry_p=0.2)),
        clock=vc, sleep=vc.sleep)
    specs = {}
    tenant_inputs = {}
    # two same-program tenants (they share compiled triggers) + one
    # distinct-shape tenant
    for i, (m, d, p) in enumerate([(8, 4, 5), (8, 4, 5), (6, 3, 4)]):
        tid = f"t{i}"
        prog, inputs = _logit_tenant(m, d, p, seed=i)
        specs[tid] = (prog, (p, d))
        tenant_inputs[tid] = inputs
        # small claims → many claims → many chaos draws per run
        fleet.add_tenant(TenantSpec(tid, prog, {"W": 1},
                                    max_claim_rank=4), inputs)
    rng = np.random.default_rng(seed + 100)
    by_lsn = {tid: {} for tid in specs}
    lsn = {tid: 0 for tid in specs}
    outcomes = {}
    for step in range(60):
        tid = f"t{rng.integers(3)}"
        p, d = specs[tid][1]
        u, v = _rank1(rng, p, d)
        assert fleet.submit(tid, "W", u, v) == ADMITTED
        lsn[tid] += 1
        by_lsn[tid][lsn[tid]] = (u, v)
        if step % 10 == 9:             # interleave refresh with ingest
            for k, n in fleet.run_until_idle(
                    workers=3,
                    on_stall=lambda: vc.advance(1.1)).items():
                outcomes[k] = outcomes.get(k, 0) + n
    for k, n in fleet.run_until_idle(workers=3,
                                     on_stall=lambda: vc.advance(1.1)
                                     ).items():
        outcomes[k] = outcomes.get(k, 0) + n
    total_committed = 0
    for tid, (prog, _) in specs.items():
        tenant = fleet.registry.get(tid)
        assert not tenant.dirty()
        assert tenant.stats.committed_updates == lsn[tid]  # exactly once
        ref = _replay_reference(tenant, tenant_inputs[tid], by_lsn[tid])
        assert max_abs_diff(tenant.committed_views, ref.views) == 0.0
        total_committed += tenant.stats.committed_updates
    assert total_committed == 60
    # chaos actually happened on every seed at these probabilities
    assert fleet.chaos.worker_crashes + fleet.chaos.lease_expiries > 0
    assert outcomes.get("committed", 0) > 0
    # same-program tenants shared compiled triggers
    assert fleet.registry.trigger_cache.stats()["hits"] > 0


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fleet_chaos_acceptance_500_firings(seed):
    """The ISSUE acceptance run: ~500 submissions across a mixed fleet
    under worker crashes, lease expiry, slow workers, poisoned updates,
    and queue-pressure overload.  Invariants: exactly-once commit
    accounting per tenant, no cross-tenant contamination (bit-identical
    per-tenant replay), and final committed views consistent with full
    re-evaluation from the tenant's own inputs."""
    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    overload=OverloadPolicy(degraded_at=0.7,
                                            shedding_at=0.9,
                                            cold_after_s=1e9),
                    chaos=ChaosConfig(seed=seed, worker_crash_p=0.1,
                                      lease_expiry_p=0.1,
                                      slow_worker_p=0.05,
                                      slow_worker_s=1.5,   # > lease TTL
                                      poison_p=0.02)),
        clock=vc, sleep=vc.sleep)
    shapes = {}
    tenant_inputs = {}
    # 3 linear logit-view tenants (two share a program) + 2 OLS tenants
    for i, (m, d, p) in enumerate([(8, 4, 5), (8, 4, 5), (6, 3, 4)]):
        tid = f"logit{i}"
        prog, inputs = _logit_tenant(m, d, p, seed=i)
        fleet.add_tenant(TenantSpec(tid, prog, {"W": 1}, slo_s=0.5,
                                    queue_capacity=64), inputs)
        shapes[tid] = ("W", (p, d))
        tenant_inputs[tid] = inputs
    for i, (m, n) in enumerate([(24, 6), (16, 4)]):
        tid = f"ols{i}"
        prog, inputs = _ols_tenant(m, n, 1, seed=10 + i)
        fleet.add_tenant(TenantSpec(tid, prog, {"X": 1}, slo_s=0.5,
                                    queue_capacity=64), inputs)
        shapes[tid] = ("X", (m, n))
        tenant_inputs[tid] = inputs
    tids = sorted(shapes)
    rng = np.random.default_rng(seed + 7)
    by_lsn = {tid: {} for tid in tids}
    admitted = {tid: 0 for tid in tids}
    submitted = 0
    for step in range(500):
        tid = tids[int(rng.integers(len(tids)))]
        input_name, (n, m) = shapes[tid]
        u, v = _rank1(rng, n, m, scale=0.05)
        decision = fleet.submit(tid, input_name, u, v)
        submitted += 1
        if decision == ADMITTED:
            admitted[tid] += 1
            # the LOG's values are what count (post-poisoning), so
            # read the entry back for the replay reference
            entry = fleet.registry.get(tid).log.pending(0)[-1]
            by_lsn[tid][entry.lsn] = (entry.u, entry.v)
        vc.advance(0.01)
        if step % 25 == 24:            # interleave refresh with ingest
            fleet.run_until_idle(workers=3,
                                 on_stall=lambda: vc.advance(1.1))
    fleet.run_until_idle(workers=3, on_stall=lambda: vc.advance(1.1))
    assert sum(admitted.values()) > 400   # queue pressure, not collapse
    for tid in tids:
        tenant = fleet.registry.get(tid)
        assert not tenant.dirty()
        # exactly-once: every admitted update is committed exactly once
        assert tenant.stats.committed_updates == admitted[tid], tid
        assert tenant.applied_lsn == admitted[tid]
        # no contamination: bit-identical to this tenant's own replay
        ref = _replay_reference(tenant, tenant_inputs[tid], by_lsn[tid])
        assert max_abs_diff(tenant.committed_views, ref.views) == 0.0, tid
        # consistency: committed views match re-evaluation from the
        # tenant's own (updated) inputs.  Linear views are tight;
        # OLS goes through an f32 inverse (repo-standard tolerance).
        fresh = IncrementalEngine(tenant.spec.program)
        fresh.initialize({k: np.asarray(tenant.committed_views[k])
                          for k in tenant.spec.program.inputs})
        for name in fresh.program.outputs:
            got = np.asarray(tenant.committed_views[name])
            want = np.asarray(fresh.views[name])
            tol = 1e-6 if tid.startswith("logit") else 2e-3
            np.testing.assert_allclose(got, want, rtol=tol,
                                       atol=tol * np.abs(want).max())
    # the fault mix actually fired
    assert fleet.chaos.worker_crashes > 0
    assert fleet.chaos.lease_expiries + fleet.leases.stats()["broken"] >= 0
    assert fleet.chaos.poisoned > 0
    stats = fleet.fleet_stats()
    assert stats["replays"] + stats["fenced_aborts"] > 0
    assert stats["trigger_cache"]["hits"] > 0


# ---------------------------------------------------------------------------
# overload tiers + degradation
# ---------------------------------------------------------------------------

def test_overload_tiers_shed_and_reeval_on_read():
    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    overload=OverloadPolicy(degraded_at=0.5,
                                            shedding_at=0.75,
                                            cold_after_s=2.0)),
        clock=vc, sleep=vc.sleep)
    prog0, inputs0 = _logit_tenant(seed=0)
    prog1, inputs1 = _logit_tenant(seed=1)
    fleet.add_tenant(TenantSpec("cold", prog0, {"W": 1}, queue_capacity=4),
                     inputs0)
    fleet.add_tenant(TenantSpec("vip", prog1, {"W": 1}, queue_capacity=4,
                                sheddable=False), inputs1)
    rng = np.random.default_rng(5)
    ups = [_rank1(rng, 5, 4) for _ in range(8)]
    assert fleet.tier() == "normal"
    vc.advance(3.0)                     # both tenants go cold
    for i in range(3):                  # load 3/8 → normal; 4/8 → degraded
        fleet.submit("cold", "W", *ups[i])
    assert fleet.tier() == "normal"
    fleet.submit("cold", "W", *ups[3])
    assert fleet.tier() == "degraded"
    cold = fleet.registry.get("cold")
    vip = fleet.registry.get("vip")
    assert cold.mode == "reeval_on_read"   # cold + sheddable → degraded
    assert vip.mode == "incremental"       # reserved capacity is spared
    for i in range(2):
        fleet.submit("vip", "W", *ups[4 + i])
    assert fleet.tier() == "shedding"      # 6/8
    assert fleet.submit("cold", "W", *ups[6]) == SHED
    assert fleet.submit("vip", "W", *ups[7]) == ADMITTED  # not sheddable
    # a degraded tenant is not scheduled; its pending deltas fold in on
    # the READ, via the same lease/commit protocol
    assert all(t.spec.tenant_id != "cold" for t in fleet._claimable())
    y = np.asarray(fleet.read("cold", "Y"))
    assert cold.stats.reeval_on_read == 1
    assert not cold.dirty()
    W = np.asarray(inputs0["W"])
    for i in range(4):
        u, v = ups[i]
        W = W + u @ v.T
    np.testing.assert_allclose(y, inputs0["H"] @ W.T, rtol=1e-5, atol=1e-5)
    # drain the vip tenant; fleet cools down and modes recover
    fleet.run_until_idle(on_stall=lambda: vc.advance(1.1))
    fleet.submit("cold", "W", *ups[7])     # any submit re-applies tiers
    assert fleet.tier() == "normal"
    assert cold.mode == "incremental"


def test_noisy_neighbor_quarantine_and_probe():
    vc = VClock()
    fleet = FleetScheduler(FleetConfig(lease_ttl=1.0), clock=vc,
                           sleep=vc.sleep)
    prog_bad, inputs_bad = _logit_tenant(seed=0)
    prog_ok, inputs_ok = _logit_tenant(seed=1)
    # every firing of the bad tenant's engine raises (injected fault);
    # the guard aborts + quarantines, the fleet's breaker opens
    fleet.add_tenant(
        TenantSpec("bad", prog_bad, {"W": 1},
                   chaos=ChaosConfig(seed=0, trigger_raise_p=1.0),
                   breaker_threshold=2, breaker_reset_s=10.0),
        inputs_bad)
    tenant_ok = fleet.add_tenant(TenantSpec("ok", prog_ok, {"W": 1}),
                                 inputs_ok)
    bad = fleet.registry.get("bad")
    last_good = dict(bad.committed_views)
    rng = np.random.default_rng(6)
    for _ in range(2):
        fleet.submit("bad", "W", *_rank1(rng, 5, 4))
        fleet.submit("ok", "W", *_rank1(rng, 5, 4))
        out = fleet.run_until_idle(on_stall=lambda: vc.advance(1.1))
        assert out.get("quarantined", 0) >= 1
    # two all-aborted claims → breaker open → tenant unschedulable
    assert bad.breaker.state == "open"
    assert bad.stats.aborted_claims == 2
    assert len(bad.engine.guard.quarantine) > 0
    fleet.submit("bad", "W", *_rank1(rng, 5, 4))
    assert fleet.run_claim("w1") == "idle"     # quarantined, skipped
    # reads still serve the last-good committed snapshot
    assert max_abs_diff({"Y": fleet.read("bad", "Y")},
                        {"Y": last_good["Y"]}) == 0.0
    # the healthy tenant was never affected
    assert tenant_ok.stats.commits == 2 and not tenant_ok.dirty()
    # after the reset window, ONE probe claim is admitted (half-open)
    vc.advance(11.0)
    assert bad.breaker.state == "half_open"
    assert fleet.run_claim("w1") == "quarantined"   # probe fails again
    assert bad.breaker.state == "open"


def test_thread_mode_smoke():
    """Live worker threads (real clock): submit, drain, verify."""
    # generous TTL: the first claim pays jit compile on a cold cache,
    # and a fenced retry (while harmless) would make the test slower
    fleet = FleetScheduler(FleetConfig(lease_ttl=10.0, workers=2))
    prog, inputs = _logit_tenant()
    tenant = fleet.add_tenant(TenantSpec("t1", prog, {"W": 1}), inputs)
    rng = np.random.default_rng(7)
    by_lsn = {}
    fleet.start()
    try:
        for i in range(12):
            u, v = _rank1(rng, 5, 4)
            assert fleet.submit("t1", "W", u, v) == ADMITTED
            by_lsn[i + 1] = (u, v)
        fleet.drain(["t1"], timeout_s=60.0)
    finally:
        fleet.stop()
    assert not tenant.dirty()
    assert tenant.stats.committed_updates == 12
    ref = _replay_reference(tenant, inputs, by_lsn)
    assert max_abs_diff(tenant.committed_views, ref.views) == 0.0


def test_serve_engine_attach_fleet():
    """ServeEngine routes hot-swap deltas / reads / health through a
    fleet-backed logit view."""
    pytest.importorskip("jax")
    import jax
    from repro.launch.train import custom_10m
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = custom_10m()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=1, max_seq=32)
    rng = np.random.default_rng(8)
    m, d, p = 6, cfg.d_model, 16
    prog = build_logit_view_program(m, d, p)
    inputs = {"H": rng.standard_normal((m, d)).astype(np.float32),
              "W": (rng.standard_normal((p, d)) * 0.1).astype(np.float32)}
    fleet = FleetScheduler(FleetConfig(lease_ttl=2.0))
    fleet.add_tenant(TenantSpec("acme", prog, {"W": 1}), inputs)
    eng.attach_fleet(fleet, {"lm_head": "acme"})
    u, v = _rank1(rng, p, d, scale=0.01)
    assert eng.hot_swap("lm_head", u, v)       # admitted into the log
    eng.flush_views()                          # drains the fleet inline
    y = np.asarray(eng.view_logits("lm_head"))
    W = np.asarray(inputs["W"]) + u @ v.T
    np.testing.assert_allclose(y, inputs["H"] @ W.T, rtol=1e-5, atol=1e-5)
    health = eng.view_health()["lm_head"]
    assert health["tenant"] == "acme" and not health["dirty"]
    with pytest.raises(ValueError):
        eng.attach_fleet(fleet, {"layers.0.mlp": "acme"})


# ---------------------------------------------------------------------------
# satellite: thread-safe TriggerCache
# ---------------------------------------------------------------------------

def test_trigger_cache_concurrent_access():
    cache = TriggerCache(capacity=8)
    built = []
    build_lock = threading.Lock()

    def builder(key):
        def make():
            with build_lock:
                built.append(key)
            return ("fn", key)
        return make

    errors = []
    results = {}

    def worker(wid):
        rng = np.random.default_rng(wid)
        try:
            for _ in range(200):
                key = ("k", int(rng.integers(16)))
                fn = cache.get_or_build(key, builder(key))
                assert fn[1] == key            # never someone else's fn
                _ = len(cache), key in cache, cache.stats()
                results[(wid, key)] = fn
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    stats = cache.stats()
    assert stats["hits"] + stats["misses"] == 8 * 200
    assert stats["entries"] <= 8               # capacity respected
    assert stats["evictions"] >= stats["misses"] - 8


def test_trigger_cache_lru_eviction_and_evict():
    cache = TriggerCache(capacity=2)
    a = cache.get_or_build(("a",), lambda: "A")
    b = cache.get_or_build(("b",), lambda: "B")
    assert cache.get_or_build(("a",), lambda: "A2") == "A"   # hit, MRU
    cache.get_or_build(("c",), lambda: "C")    # evicts LRU = ("b",)
    assert ("b",) not in cache and ("a",) in cache
    assert cache.stats()["evictions"] == 1
    assert cache.evict(("a",)) and not cache.evict(("a",))
    assert len(cache) == 1
    with pytest.raises(ValueError):
        TriggerCache(capacity=0)


# ---------------------------------------------------------------------------
# satellite: chain-aware planner pricing
# ---------------------------------------------------------------------------

def test_chain_aware_pricing_demotes_lone_survivors():
    """When siblings re-evaluate, a lone incremental view bears the
    whole shared delta chain — chain-aware pricing must lower its
    effective crossover (never raise it)."""
    prog = build_ols_program(96, 12, 2)
    compiled = compile_program(prog, {"X": 1})
    base = plan_program(compiled, WorkloadDescriptor(update_rank=1,
                                                     batch_size=8))
    aware = plan_program(compiled, WorkloadDescriptor(update_rank=1,
                                                      batch_size=8,
                                                      chain_aware=True))
    order = {"reeval": 0, "hybrid": 1, "incremental": 2}
    demoted = 0
    for name, vp in aware.views.items():
        bp = base.views[name]
        assert order[vp.strategy] <= order[bp.strategy], name
        if vp.strategy != bp.strategy:
            demoted += 1
        if vp.strategy == "hybrid" and bp.strategy == "hybrid":
            assert vp.threshold_rank <= bp.threshold_rank
    assert demoted >= 1        # the chain price moved at least one view

    # a chain-aware plan still executes correctly
    rng = np.random.default_rng(9)
    inputs = {"X": rng.standard_normal((96, 12)).astype(np.float32),
              "Y": rng.standard_normal((96, 2)).astype(np.float32)}
    eng = IncrementalEngine(prog, {"X": 1}, plan=aware,
                            trigger_cache=TriggerCache())
    ref = IncrementalEngine(prog, {"X": 1})
    eng.initialize(inputs)
    ref.initialize(inputs)
    ups = [_rank1(rng, 96, 12, scale=0.05) for _ in range(4)]
    eng.apply_updates("X", ups)
    ref.apply_updates("X", ups)
    eng.refresh()
    for name in prog.outputs:
        np.testing.assert_allclose(np.asarray(eng.views[name]),
                                   np.asarray(ref.views[name]),
                                   rtol=2e-3, atol=2e-3)


def test_firing_cost_flops_prices_the_chain():
    prog = build_ols_program(96, 12, 2)
    compiled = compile_program(prog, {"X": 1})
    binding = dict(prog.dims)
    assign_flops, view_deps = trigger_chain_costs(
        compiled.triggers["X"], binding)
    assert all(c > 0 for c in assign_flops.values())
    c1 = firing_cost_flops(compiled, binding, "X", 1)
    c8 = firing_cost_flops(compiled, binding, "X", 8)
    assert c8 > c1 > 0                       # monotone in stacked rank
    # re-evaluating a view swaps its sweep for its reeval cost and can
    # only drop chain assigns, never add them
    views = [up.view for up in compiled.triggers["X"].updates
             if up.view in {s.target.name for s in prog.statements}]
    c_re = firing_cost_flops(compiled, binding, "X", 8,
                             reeval_views=frozenset(views[:1]))
    assert c_re != c8 and c_re > 0


# ---------------------------------------------------------------------------
# satellite: deterministic degrade (clock + jitter + single probe)
# ---------------------------------------------------------------------------

def test_retry_with_backoff_injectable_clock_and_deadline():
    vc = VClock()
    sleeps = []

    def sleep(dt):
        sleeps.append(dt)
        vc.advance(dt)

    calls = []

    def always_fails():
        calls.append(vc())
        raise RuntimeError("down")

    policy = DegradePolicy(max_retries=50, backoff_base=0.5,
                           backoff_max=8.0, retry_deadline=3.0,
                           full_jitter=False, jitter=0.0)
    rng = np.random.default_rng(0)
    with pytest.raises(RuntimeError):
        retry_with_backoff(always_fails, policy, rng, sleep=sleep,
                           clock=vc)
    # deadline bounded the loop long before 50 retries
    assert len(calls) < 10
    assert vc() <= 3.0 + 8.0               # never sleeps past the budget


def test_retry_full_jitter_decorrelates():
    vc = VClock()
    sleeps = []

    def sleep(dt):
        sleeps.append(dt)
        vc.advance(dt)

    def fails():
        raise RuntimeError("down")

    policy = DegradePolicy(max_retries=6, backoff_base=1.0,
                           backoff_max=4.0, full_jitter=True)
    with pytest.raises(RuntimeError):
        retry_with_backoff(fails, policy, np.random.default_rng(1),
                           sleep=sleep, clock=vc)
    assert len(sleeps) == 6                # one pause per retry
    # full jitter: uniform in [0, min(base·2^i, cap)] — all draws in
    # range, and (statistically certain for this seed) not lock-step
    caps = [min(1.0 * 2 ** i, 4.0) for i in range(len(sleeps))]
    assert all(0.0 <= s <= c for s, c in zip(sleeps, caps))
    assert len({round(s / c, 6) for s, c in zip(sleeps, caps)}) > 1


def test_breaker_half_open_single_probe():
    vc = VClock()
    br = CircuitBreaker(threshold=2, reset_timeout=5.0, clock=vc)
    assert br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == "open" and not br.allow()
    vc.advance(5.0)
    assert br.state == "half_open"
    assert br.allow()                      # the single probe
    assert not br.allow()                  # concurrent caller: wait
    br.record_failure()                    # probe failed → open again
    assert br.state == "open"
    vc.advance(5.0)
    assert br.allow()
    br.record_success()                    # probe succeeded → closed
    assert br.state == "closed" and br.allow()


def test_breaker_abandoned_probe_rearms():
    vc = VClock()
    br = CircuitBreaker(threshold=1, reset_timeout=2.0, clock=vc)
    br.record_failure()
    vc.advance(2.0)
    assert br.allow()                      # probe claimed …
    assert not br.allow()                  # … and in flight
    vc.advance(2.0)                        # prober crashed; window re-arms
    assert br.allow()

# ---------------------------------------------------------------------------
# higher-order (deferred-cascade) tenants under fleet chaos (ISSUE 8)
# ---------------------------------------------------------------------------

def _replay_with_opts(tenant, inputs, updates_by_lsn):
    """Isolated replay honoring the tenant's engine_opts (order,
    fold_window, …) — a deferred tenant must be replayed by a deferred
    engine for bit-identity to be achievable."""
    ref = IncrementalEngine(tenant.spec.program, tenant.spec.update_ranks,
                            guard=tenant.spec.guarded or None,
                            **tenant.spec.engine_opts)
    ref.initialize(inputs)
    for input_name, lsns in tenant.commit_log:
        assert input_name != "<reeval>", "differential test must not degrade"
        ref.apply_updates(input_name,
                          [updates_by_lsn[l] for l in lsns])
    return ref


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fleet_higher_order_chaos_bit_identical_and_exact(seed):
    """ISSUE 8 differential: a 5-tenant fleet in which two tenants run
    order-2 deferred engines (``TenantSpec.engine_opts``) under worker
    crashes, lease expiry, and poison.  Invariants: exactly-once
    commit accounting; committed stores bit-identical to same-order
    isolated replays (aborted/replayed firings never tick a cascade
    window twice); and, after a fold barrier, numeric agreement with a
    clean FIRST-order replay of the same committed groups."""
    vc = VClock()
    fleet = FleetScheduler(
        FleetConfig(lease_ttl=1.0,
                    chaos=ChaosConfig(seed=seed, worker_crash_p=0.15,
                                      lease_expiry_p=0.1, poison_p=0.02)),
        clock=vc, sleep=vc.sleep)
    from repro.apps.matrix_powers import build_powers_program
    shapes, tenant_inputs = {}, {}
    rng0 = np.random.default_rng(99)
    for i in range(3):   # two deferred tenants + one first-order control
        tid = f"pow{i}"
        prog = build_powers_program(k=4, n=10, model="exp")
        a = rng0.standard_normal((10, 10)).astype(np.float32)
        a *= 0.5 / max(abs(np.linalg.eigvals(a)))
        opts = {"order": 2, "fold_window": 2} if i < 2 else {}
        fleet.add_tenant(TenantSpec(tid, prog, {"A": 1}, max_claim_rank=4,
                                    engine_opts=opts), {"A": a})
        shapes[tid] = ("A", (10, 10))
        tenant_inputs[tid] = {"A": a}
    for i, (m, d, p) in enumerate([(8, 4, 5), (6, 3, 4)]):
        tid = f"logit{i}"
        prog, inputs = _logit_tenant(m, d, p, seed=i)
        fleet.add_tenant(TenantSpec(tid, prog, {"W": 1},
                                    max_claim_rank=4), inputs)
        shapes[tid] = ("W", (p, d))
        tenant_inputs[tid] = inputs
    assert fleet.registry.get("pow0").engine._deferred
    assert not fleet.registry.get("pow2").engine._deferred

    tids = sorted(shapes)
    rng = np.random.default_rng(seed + 5)
    by_lsn = {tid: {} for tid in tids}
    admitted = {tid: 0 for tid in tids}
    for step in range(150):
        tid = tids[int(rng.integers(len(tids)))]
        input_name, (n, m) = shapes[tid]
        u, v = _rank1(rng, n, m, scale=0.02)
        if fleet.submit(tid, input_name, u, v) == ADMITTED:
            admitted[tid] += 1
            entry = fleet.registry.get(tid).log.pending(0)[-1]
            by_lsn[tid][entry.lsn] = (entry.u, entry.v)
        vc.advance(0.01)
        if step % 25 == 24:
            fleet.run_until_idle(workers=3,
                                 on_stall=lambda: vc.advance(1.1))
    fleet.run_until_idle(workers=3, on_stall=lambda: vc.advance(1.1))
    assert fleet.chaos.worker_crashes + fleet.chaos.lease_expiries > 0

    for tid in tids:
        tenant = fleet.registry.get(tid)
        assert not tenant.dirty()
        assert tenant.stats.committed_updates == admitted[tid], tid
        ref = _replay_with_opts(tenant, tenant_inputs[tid], by_lsn[tid])
        assert max_abs_diff(tenant.committed_views, ref.views) == 0.0, tid
        # fold barrier, then the first-order differential.  5e-6
        # scale-normalized: two float32 maintenance paths (per-firing
        # sweeps vs window folds) drift apart by a few ulps per firing.
        views = dict(tenant.engine.flush())
        first = IncrementalEngine(tenant.spec.program,
                                  tenant.spec.update_ranks,
                                  guard=tenant.spec.guarded or None)
        first.initialize(tenant_inputs[tid])
        for input_name, lsns in tenant.commit_log:
            first.apply_updates(input_name,
                                [by_lsn[tid][l] for l in lsns])
        for st in tenant.spec.program.statements:
            name = st.target.name
            want = np.asarray(first.views[name], np.float64)
            got = np.asarray(views[name], np.float64)
            err = np.abs(got - want).max() / max(np.abs(want).max(), 1.0)
            assert err <= 5e-6, f"{tid}/{name}: {err:.2e}"
    # deferred tenants actually exercised the cascade under chaos
    assert fleet.registry.get("pow0").engine.stats.folds > 0 or \
        fleet.registry.get("pow1").engine.stats.folds > 0
